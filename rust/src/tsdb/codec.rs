//! # tsdb::codec — fast float/int codecs for the line-protocol layer
//!
//! Every stored point crosses the wire format twice: once formatted
//! (save/export) and once parsed (load/ingest). The generic stdlib
//! paths (`format!("{}")`, `str::parse`) are correct but carry the full
//! Grisu/Dragon rendering and arbitrary-precision parsing machinery on
//! every call. This module supplies the hot-path codecs with a hard
//! compatibility contract:
//!
//! > **Byte-identical to the stdlib paths on every input.** The fast
//! > paths only fire where the result is *provably* the one the stdlib
//! > would produce; everything else falls through to the stdlib. The
//! > `codec_prop` suite fuzzes the equivalence.
//!
//! Why this shape (instead of a full Grisu/Eisel-Lemire port):
//!
//! * **Formatting** ([`fmt_f64`]): benchmark fields are overwhelmingly
//!   "integral-valued doubles" (counts, byte totals, round durations).
//!   For finite integral `|v| < 2^53` the shortest round-trip decimal
//!   *is* the exact integer (any shorter positional decimal would be a
//!   multiple of 10 at distance ≥ 1 > ulp/2, and Rust's `Display`
//!   renders shortest-digits positionally), so an itoa-style digit loop
//!   is exact. Non-integral values use `Display` itself — identical by
//!   definition, and rarer.
//! * **Parsing** ([`parse_f64`]): the Clinger fast path. A mantissa
//!   that fits `f64` exactly (`< 2^53`) scaled by an exactly
//!   representable power of ten (`|exp10| ≤ 22`) takes a *single*
//!   correctly-rounded multiply/divide — which is the correctly rounded
//!   decimal value, i.e. exactly what the stdlib's correctly rounded
//!   parser returns. Longer mantissas, exponent syntax, `inf`/`NaN`
//!   spellings and malformed input all delegate, so error *values*
//!   (and acceptance) match the stdlib bit for bit.
//!
//! Integer codecs ([`fmt_i64`], [`parse_i64`]) follow the same pattern
//! (≤ 18-digit fast path; overflow and odd spellings delegate).

/// Append the decimal digits of `v` (itoa-style, no allocation beyond
/// what `out` may grow by).
#[inline]
pub fn fmt_u64(mut v: u64, out: &mut String) {
    let mut buf = [0u8; 20];
    let mut i = buf.len();
    loop {
        i -= 1;
        buf[i] = b'0' + (v % 10) as u8;
        v /= 10;
        if v == 0 {
            break;
        }
    }
    // digits are ASCII by construction
    out.push_str(std::str::from_utf8(&buf[i..]).expect("ascii digits"));
}

/// Append `v` formatted exactly as `i64`'s `Display` would.
#[inline]
pub fn fmt_i64(v: i64, out: &mut String) {
    if v < 0 {
        out.push('-');
        fmt_u64(v.unsigned_abs(), out);
    } else {
        fmt_u64(v as u64, out);
    }
}

/// Largest double below which every integral value is exactly
/// representable (2^53): the integral fast-path bound.
const MAX_EXACT_INT: f64 = 9_007_199_254_740_992.0;

/// Append `v` formatted **byte-identically** to `format!("{v}")`.
///
/// Fast path: finite integral `|v| < 2^53` renders through the integer
/// digit loop (see the module docs for why that is exactly `Display`'s
/// output). Everything else — fractional values, huge magnitudes,
/// subnormals, `NaN`, infinities — delegates to `Display` itself.
pub fn fmt_f64(v: f64, out: &mut String) {
    if v.is_nan() {
        out.push_str("NaN");
        return;
    }
    if v.is_infinite() {
        out.push_str(if v.is_sign_negative() { "-inf" } else { "inf" });
        return;
    }
    // `-0.0 < 0.0` is false: split on the sign bit so "-0" survives
    let a = if v.is_sign_negative() {
        out.push('-');
        -v
    } else {
        v
    };
    if a < MAX_EXACT_INT && a == a.trunc() {
        fmt_u64(a as u64, out);
    } else {
        use std::fmt::Write as _;
        let _ = write!(out, "{a}");
    }
}

/// Exact powers of ten: every entry is exactly representable in `f64`
/// (10^22 = 2^22 · 5^22, and 5^22 < 2^53), which is what makes the
/// Clinger one-operation scaling correctly rounded.
const POW10: [f64; 23] = [
    1e0, 1e1, 1e2, 1e3, 1e4, 1e5, 1e6, 1e7, 1e8, 1e9, 1e10, 1e11, 1e12, 1e13, 1e14, 1e15, 1e16,
    1e17, 1e18, 1e19, 1e20, 1e21, 1e22,
];

/// Mantissas below 2^53 convert to `f64` without rounding.
const MAX_EXACT_MANTISSA: u64 = 1 << 53;

/// Parse `s` with results (including rejections) **identical to
/// `s.parse::<f64>()`**. Plain `[-]ddd[.ddd]` decimals within the
/// Clinger window parse in one pass; anything else — exponents, inf/nan
/// spellings, a leading `+`, too many digits — delegates to the stdlib,
/// so acceptance and error values cannot diverge.
pub fn parse_f64(s: &str) -> Result<f64, std::num::ParseFloatError> {
    let b = s.as_bytes();
    let mut i = 0usize;
    let neg = match b.first() {
        Some(b'-') => {
            i = 1;
            true
        }
        _ => false,
    };
    let mut mant: u64 = 0;
    let mut digits = 0usize;
    let mut exp10: i32 = 0;
    let mut seen_digit = false;
    while i < b.len() && b[i].is_ascii_digit() {
        if digits == 19 {
            return s.parse(); // could overflow the u64 accumulator
        }
        mant = mant * 10 + (b[i] - b'0') as u64;
        digits += 1;
        seen_digit = true;
        i += 1;
    }
    if i < b.len() && b[i] == b'.' {
        i += 1;
        while i < b.len() && b[i].is_ascii_digit() {
            if digits == 19 {
                return s.parse();
            }
            mant = mant * 10 + (b[i] - b'0') as u64;
            digits += 1;
            exp10 -= 1;
            seen_digit = true;
            i += 1;
        }
    }
    if !seen_digit || i != b.len() {
        // exponent syntax, inf/NaN, stray characters, empty input:
        // let the stdlib decide (and produce its exact error)
        return s.parse();
    }
    if mant >= MAX_EXACT_MANTISSA || !(-22..=22).contains(&exp10) {
        return s.parse();
    }
    // `mant` is exact; one multiply/divide by an exact power of ten is
    // one correctly-rounded operation on the exact decimal value
    let mut x = mant as f64;
    if exp10 > 0 {
        x *= POW10[exp10 as usize];
    } else if exp10 < 0 {
        x /= POW10[(-exp10) as usize];
    }
    Ok(if neg { -x } else { x })
}

/// Parse `s` with results identical to `s.parse::<i64>()`. Up to 18
/// digits cannot overflow; longer inputs (and `+`-prefixed or malformed
/// ones) delegate to the stdlib for exact acceptance/error parity.
pub fn parse_i64(s: &str) -> Result<i64, std::num::ParseIntError> {
    let b = s.as_bytes();
    let (neg, rest) = match b.first() {
        Some(b'-') => (true, &b[1..]),
        _ => (false, b),
    };
    if rest.is_empty() || rest.len() > 18 {
        return s.parse();
    }
    let mut v: i64 = 0;
    for &c in rest {
        if !c.is_ascii_digit() {
            return s.parse();
        }
        v = v * 10 + (c - b'0') as i64;
    }
    Ok(if neg { -v } else { v })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fmt(v: f64) -> String {
        let mut s = String::new();
        fmt_f64(v, &mut s);
        s
    }

    #[test]
    fn fmt_matches_display_on_fixtures() {
        for v in [
            0.0,
            -0.0,
            1.0,
            -1.0,
            42.0,
            1e15,
            9_007_199_254_740_991.0, // 2^53 - 1: last exact integer
            9_007_199_254_740_992.0, // 2^53: falls through to Display
            0.1,
            -0.30000000000000004,
            1.7976931348623157e308,
            5e-324,
            -1234567890.123456,
            f64::NAN,
            f64::INFINITY,
            f64::NEG_INFINITY,
            2.5e-10,
        ] {
            assert_eq!(fmt(v), format!("{v}"), "value {v:e}");
        }
    }

    #[test]
    fn parse_matches_stdlib_on_fixtures() {
        for s in [
            "0", "-0", "1", "-1", "42", "0.5", "-0.5", "1.", ".5", "123.456",
            "9007199254740991", "9007199254740992", "1e3", "-2.5E-4", "inf", "-inf", "NaN",
            "nan", "+1", "", "abc", "1.2.3", "0.000000000000000000000001", "5e-324",
            "1797693134862315700000", "--1", "1-",
        ] {
            match (parse_f64(s), s.parse::<f64>()) {
                (Ok(a), Ok(b)) => {
                    assert_eq!(a.to_bits(), b.to_bits(), "input {s:?}");
                }
                (Err(_), Err(_)) => {}
                (a, b) => panic!("input {s:?}: fast {a:?} vs stdlib {b:?}"),
            }
        }
    }

    #[test]
    fn parse_i64_matches_stdlib_on_fixtures() {
        for s in [
            "0", "-0", "1", "-1", "123456789", "-987654321", "999999999999999999",
            "9223372036854775807", "-9223372036854775808", "9223372036854775808", "+5", "",
            "12a", "-", "007",
        ] {
            match (parse_i64(s), s.parse::<i64>()) {
                (Ok(a), Ok(b)) => assert_eq!(a, b, "input {s:?}"),
                (Err(_), Err(_)) => {}
                (a, b) => panic!("input {s:?}: fast {a:?} vs stdlib {b:?}"),
            }
        }
    }

    #[test]
    fn fmt_i64_matches_display() {
        for v in [0i64, 1, -1, 42, -42, i64::MAX, i64::MIN, 1_000_000_000] {
            let mut s = String::new();
            fmt_i64(v, &mut s);
            assert_eq!(s, v.to_string());
        }
    }

    #[test]
    fn roundtrip_through_the_codec_is_lossless() {
        for v in [0.1, -0.30000000000000004, 1.7976931348623157e308, 5e-324, 123456.0, -0.0] {
            let s = fmt(v);
            let back = parse_f64(&s).unwrap();
            assert_eq!(back.to_bits(), v.to_bits(), "value {v:e} via {s:?}");
        }
    }
}
