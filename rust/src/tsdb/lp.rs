//! # tsdb::lp — zero-copy batched line-protocol parsing
//!
//! The original [`super::Point::parse_line`] built every token one
//! `char` at a time through intermediate `String`s — ~10 allocations
//! per line before the [`super::Point`] even existed. This module is
//! the rewrite the ingest hot path runs on:
//!
//! * **Zero-copy splitting**: sections, tags and fields are `&str`
//!   slices borrowed straight from the input line, found by a single
//!   byte scan for unescaped delimiters. All delimiters (`\`, space,
//!   `,`, `=`) are ASCII, and UTF-8 guarantees no continuation byte
//!   collides with them, so the byte scan is exact on any input.
//! * **Allocate only on escapes**: [`unescape`] returns
//!   `Cow::Borrowed` for the (overwhelmingly common) token without a
//!   backslash; only tokens that actually carry escapes buy a `String`.
//!   The owned [`super::Point`] is built directly from the cow slices.
//! * **Batched, parallel parses**: [`parse_lines`] splits a whole
//!   upload batch serially (cheap) and parses chunks of lines across
//!   the [`crate::par`] pool, preserving input order — and therefore
//!   byte-identical results — for any thread count. Errors surface in
//!   input order, exactly like a serial loop.
//!
//! Semantics are bit-for-bit those of the old parser (same accepted
//! grammar, same error strings, trailing lone backslashes dropped by
//! unescaping, field *values* parsed without unescaping) — the
//! round-trip property suite and the PR 1 escape/negative-timestamp/
//! extreme-value fixtures run against this implementation through the
//! unchanged `Point::parse_line` entry point.

use super::codec;
use super::Point;
use crate::par;
use std::borrow::Cow;

/// Below this many lines a batch parse stays serial — spawning workers
/// costs more than the parse.
pub(crate) const PAR_MIN_LINES: usize = 512;

/// Remove line-protocol escapes. Borrowed when there is nothing to do;
/// a lone trailing backslash is dropped (as the old parser did).
fn unescape(s: &str) -> Cow<'_, str> {
    if !s.as_bytes().contains(&b'\\') {
        return Cow::Borrowed(s);
    }
    let mut out = String::with_capacity(s.len());
    let mut esc = false;
    for c in s.chars() {
        if esc {
            out.push(c);
            esc = false;
        } else if c == '\\' {
            esc = true;
        } else {
            out.push(c);
        }
    }
    Cow::Owned(out)
}

/// Split `s` on unescaped `sep` (an ASCII delimiter), borrowing every
/// part. Escapes are kept in the parts — [`unescape`] strips them later,
/// mirroring the two-phase structure of the old parser.
fn split_unescaped(s: &str, sep: u8) -> Vec<&str> {
    let bytes = s.as_bytes();
    let mut parts = Vec::new();
    let mut start = 0usize;
    let mut i = 0usize;
    while i < bytes.len() {
        if bytes[i] == b'\\' {
            i += 2; // skip the escaped byte (a trailing `\` just ends the scan)
        } else if bytes[i] == sep {
            parts.push(&s[start..i]);
            start = i + 1;
            i += 1;
        } else {
            i += 1;
        }
    }
    parts.push(&s[start..]);
    parts
}

/// Escape line-protocol specials into `out` — byte-identical to the
/// chained `str::replace` escaping the original `Point::to_line` used,
/// without its four intermediate `String`s per token.
pub(crate) fn escape_into(s: &str, out: &mut String) {
    for c in s.chars() {
        if matches!(c, '\\' | ',' | ' ' | '=') {
            out.push('\\');
        }
        out.push(c);
    }
}

/// One parsed line in raw (pre-`Point`) form: unescaped tokens borrowed
/// from the input wherever possible, tag and field pairs **key-sorted
/// with duplicate keys last-wins** (the `BTreeMap` insert semantics the
/// old parser had implicitly). The vectors are scratch: reuse one
/// `RawLine` across a whole batch and the steady-state parse allocates
/// nothing per line.
pub(crate) struct RawLine<'t> {
    pub measurement: Cow<'t, str>,
    pub tags: Vec<(Cow<'t, str>, Cow<'t, str>)>,
    pub fields: Vec<(Cow<'t, str>, f64)>,
    pub ts: i64,
}

impl Default for RawLine<'_> {
    fn default() -> Self {
        RawLine {
            measurement: Cow::Borrowed(""),
            tags: Vec::new(),
            fields: Vec::new(),
            ts: 0,
        }
    }
}

/// Key-sort `v` (stable) and keep only the last entry of each equal-key
/// run — exactly what inserting the pairs into a `BTreeMap` in input
/// order produces. The strictly-sorted common case is a single scan.
fn sort_dedup_pairs<'t, T>(v: &mut Vec<(Cow<'t, str>, T)>) {
    if v.len() < 2 || v.windows(2).all(|w| w[0].0 < w[1].0) {
        return;
    }
    v.sort_by(|a, b| a.0.cmp(&b.0));
    let mut i = 0;
    while i + 1 < v.len() {
        if v[i].0 == v[i + 1].0 {
            v.remove(i); // stable sort kept input order: drop the earlier
        } else {
            i += 1;
        }
    }
}

/// Parse one line-protocol line into a reusable [`RawLine`]. The single
/// grammar implementation: [`parse_line`] (owned `Point`s) and the
/// columnar ingest in [`super::col`] are both built on it, so accepted
/// inputs and error strings cannot diverge between the two paths.
pub(crate) fn parse_line_into<'t>(line: &'t str, out: &mut RawLine<'t>) -> Result<(), String> {
    out.tags.clear();
    out.fields.clear();
    // split into 3 sections on the first two unescaped spaces
    let bytes = line.as_bytes();
    let mut sections: [&str; 3] = ["", "", ""];
    let mut n_sections = 0usize;
    let mut start = 0usize;
    let mut i = 0usize;
    while i < bytes.len() {
        if bytes[i] == b'\\' {
            i += 2;
        } else if bytes[i] == b' ' && n_sections < 2 {
            sections[n_sections] = &line[start..i];
            n_sections += 1;
            start = i + 1;
            i += 1;
        } else {
            i += 1;
        }
    }
    sections[n_sections] = &line[start..];
    n_sections += 1;
    if n_sections != 3 {
        return Err(format!("expected 3 sections, got {n_sections}"));
    }

    // measurement + tags: split on unescaped commas
    let head = split_unescaped(sections[0], b',');
    out.measurement = unescape(head[0]);
    for t in &head[1..] {
        let kv = split_unescaped(t, b'=');
        if kv.len() != 2 {
            return Err(format!("bad tag `{t}`"));
        }
        out.tags.push((unescape(kv[0]), unescape(kv[1])));
    }
    for f in split_unescaped(sections[1], b',') {
        let kv = split_unescaped(f, b'=');
        if kv.len() != 2 {
            return Err(format!("bad field `{f}`"));
        }
        // field values are parsed raw (floats never carry escapes) —
        // old-parser semantics, kept bit-for-bit by the codec contract
        let v: f64 =
            codec::parse_f64(kv[1]).map_err(|_| format!("bad field value `{}`", kv[1]))?;
        out.fields.push((unescape(kv[0]), v));
    }
    out.ts = codec::parse_i64(sections[2].trim())
        .map_err(|_| format!("bad timestamp `{}`", sections[2]))?;
    if out.fields.is_empty() {
        return Err("point has no fields".into());
    }
    sort_dedup_pairs(&mut out.tags);
    sort_dedup_pairs(&mut out.fields);
    Ok(())
}

/// Parse one line-protocol line
/// (`measurement,tag=v,... field=v,... ts`) into an owned [`Point`].
/// The workhorse behind [`Point::parse_line`].
pub fn parse_line(line: &str) -> Result<Point, String> {
    let mut raw = RawLine::default();
    parse_line_into(line, &mut raw)?;
    let mut p = Point::new(&raw.measurement, raw.ts);
    for (k, v) in raw.tags.drain(..) {
        p.tags.insert(k.into_owned(), v.into_owned());
    }
    for (k, v) in raw.fields.drain(..) {
        p.fields.insert(k.into_owned(), v);
    }
    Ok(p)
}

/// Parse a whole batch of line-protocol text, in input order. Blank
/// lines and `#` comments are skipped (the `Db::ingest_lines`
/// convention). Large batches parse in chunks across the [`crate::par`]
/// pool; the result — points *and* the error a malformed batch
/// surfaces — is identical for any thread count.
pub fn parse_lines(text: &str) -> Result<Vec<Point>, String> {
    let lines: Vec<&str> = text
        .lines()
        .map(str::trim)
        .filter(|l| !l.is_empty() && !l.starts_with('#'))
        .collect();
    if lines.len() < PAR_MIN_LINES || par::threads() <= 1 || par::in_worker() {
        return lines.iter().map(|l| parse_line(l)).collect();
    }
    // chunk so every worker sees a few batches (work-queue balancing
    // without work stealing), but never below the serial threshold
    let chunk = (lines.len() / (par::threads() * 4)).max(PAR_MIN_LINES / 4);
    let chunks: Vec<&[&str]> = lines.chunks(chunk).collect();
    let parsed = par::try_map(chunks, |c| {
        c.iter().map(|l| parse_line(l)).collect::<Result<Vec<Point>, String>>()
    })?;
    Ok(parsed.into_iter().flatten().collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn borrowed_unless_escaped() {
        assert!(matches!(unescape("plain_token"), Cow::Borrowed(_)));
        assert!(matches!(unescape("esc\\,aped"), Cow::Owned(_)));
        assert_eq!(unescape("a\\ b\\=c\\,d\\\\e"), "a b=c,d\\e");
        // a lone trailing backslash is dropped, like the old parser
        assert_eq!(unescape("tail\\"), "tail");
    }

    #[test]
    fn split_keeps_escapes_for_the_unescape_phase() {
        assert_eq!(split_unescaped("a,b\\,c,d", b','), vec!["a", "b\\,c", "d"]);
        assert_eq!(split_unescaped("", b','), vec![""]);
        assert_eq!(split_unescaped("k\\=v=x", b'='), vec!["k\\=v", "x"]);
    }

    #[test]
    fn batch_parse_matches_per_line_parse_and_skips_comments() {
        let text = "m,t=a v=1 10\n# comment\n\n  m,t=b v=2.5 20  \nm v=3 -30\n";
        let batch = parse_lines(text).unwrap();
        let single: Vec<Point> = text
            .lines()
            .map(str::trim)
            .filter(|l| !l.is_empty() && !l.starts_with('#'))
            .map(|l| parse_line(l).unwrap())
            .collect();
        assert_eq!(batch, single);
        assert_eq!(batch.len(), 3);
        assert_eq!(batch[2].ts, -30);
    }

    #[test]
    fn batch_error_is_the_first_bad_line() {
        let text = "m v=1 1\nm v=x 2\nnot_a_point\n";
        let err = parse_lines(text).unwrap_err();
        assert_eq!(err, "bad field value `x`");
    }

    #[test]
    fn duplicate_keys_last_wins_like_btreemap() {
        let p = parse_line("m,t=a,t=b,s=x v=1,v=2,w=3 5").unwrap();
        assert_eq!(p.tags["t"], "b");
        assert_eq!(p.tags["s"], "x");
        assert_eq!(p.fields["v"], 2.0);
        assert_eq!(p.fields["w"], 3.0);
    }

    #[test]
    fn escape_into_matches_chained_replace() {
        for s in ["plain", "a,b c=d\\e", "tail\\", " ", "=,\\ ", ""] {
            let mut out = String::new();
            escape_into(s, &mut out);
            let legacy = s
                .replace('\\', "\\\\")
                .replace(',', "\\,")
                .replace(' ', "\\ ")
                .replace('=', "\\=");
            assert_eq!(out, legacy, "token {s:?}");
        }
    }
}
