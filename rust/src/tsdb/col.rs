//! # tsdb::col — the interned, columnar shard body
//!
//! The storage rewrite behind "raw speed, round 2". A shard used to hold
//! `Vec<Point>`: every ingested point carried an owned `String`
//! measurement plus two `BTreeMap`s of owned `String` keys/values —
//! ~10 allocations per point before any query ran, and the same strings
//! ("node", "icx36", "mlups", …) re-allocated for every single point of
//! a 200k-line upload. This module replaces that body with:
//!
//! * [`Interner`] — one per [`super::Db`], mapping tag keys/values,
//!   field names and measurement names to `u32` symbols (and whole
//!   key-sorted tag sets to a single `u32` tag-set id). Read-mostly:
//!   a hit costs one `RwLock` read acquisition and a hash lookup, no
//!   allocation. Symbol *ids* are assignment-ordered and therefore not
//!   stable across runs — nothing persistent or ordered may depend on
//!   them; every rendering/sorting decision goes through the resolved
//!   strings.
//! * [`Columns`] — a structure-of-arrays shard body: `ts` column,
//!   tag-set id column, and a flat field plane (`field_syms` /
//!   `field_vals` sliced by per-row end offsets). Per-point field *sets*
//!   vary across series, so fields are row-grouped rather than stored as
//!   per-field dense columns; within a row they are kept sorted by field
//!   name string — the `BTreeMap` iteration order the wire format and
//!   every downstream consumer already assume.
//!
//! The compatibility boundary is the **line-protocol codec**: parsing
//! interns straight into `Columns` ([`parse_chunk`]), rendering walks
//! `Columns` straight into escaped lp text ([`Columns::render_row`],
//! byte-identical to [`super::Point::to_line`]), and the owned
//! [`super::Point`] form is materialized lazily only where the public
//! API hands out `&Point` ([`Columns::to_points`], cached per shard).

use super::lp;
use super::Point;
use crate::obs::metrics as om;
use crate::tsdb::codec;
use std::collections::{BTreeMap, BTreeSet, HashMap};
use std::sync::{Arc, RwLock};

/// Per-database string/tag-set interner. Thread-safe (`RwLock`): the
/// parallel parse workers intern concurrently; the double-checked write
/// path keeps every distinct string allocated exactly once.
#[derive(Debug, Default)]
pub struct Interner {
    inner: RwLock<Inner>,
}

#[derive(Debug, Default)]
struct Inner {
    syms: HashMap<Arc<str>, u32>,
    pool: Vec<Arc<str>>,
    tagsets: HashMap<Arc<[(u32, u32)]>, u32>,
    tagset_pool: Vec<Arc<[(u32, u32)]>>,
}

/// Interner size summary (MEMORY_JSON in the bench report).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct InternerStats {
    /// Distinct interned strings.
    pub strings: usize,
    /// Distinct interned tag sets.
    pub tagsets: usize,
    /// Approximate resident bytes (string bytes + table overhead).
    pub approx_bytes: usize,
}

impl Interner {
    /// Symbol of `s`, interning it on first sight.
    pub fn intern(&self, s: &str) -> u32 {
        if let Some(&id) = self.inner.read().unwrap().syms.get(s) {
            om::add(om::Counter::InternHits, 1);
            return id;
        }
        let mut w = self.inner.write().unwrap();
        if let Some(&id) = w.syms.get(s) {
            // raced another interning thread — it won
            om::add(om::Counter::InternHits, 1);
            return id;
        }
        om::add(om::Counter::InternMisses, 1);
        let a: Arc<str> = Arc::from(s);
        let id = w.pool.len() as u32;
        w.pool.push(a.clone());
        w.syms.insert(a, id);
        id
    }

    /// Symbol of `s` if it was ever interned — never inserts (the
    /// read-only probe for marker tags like `rollup`).
    pub fn lookup(&self, s: &str) -> Option<u32> {
        self.inner.read().unwrap().syms.get(s).copied()
    }

    /// The pooled string behind `sym` (shared, not copied).
    pub fn get(&self, sym: u32) -> Arc<str> {
        self.inner.read().unwrap().pool[sym as usize].clone()
    }

    /// Intern `s` and hand back the pooled `Arc<str>` — the shard
    /// `meas` handle shares the interner's single allocation.
    pub fn intern_arc(&self, s: &str) -> Arc<str> {
        let id = self.intern(s);
        self.get(id)
    }

    /// Tag-set id of `pairs`, which MUST be sorted by key *string*
    /// (the `BTreeMap` order every producer in this module maintains) —
    /// equal tag sets then share one id by construction.
    pub fn tagset_of(&self, pairs: &[(u32, u32)]) -> u32 {
        if let Some(&id) = self.inner.read().unwrap().tagsets.get(pairs) {
            om::add(om::Counter::InternHits, 1);
            return id;
        }
        let mut w = self.inner.write().unwrap();
        if let Some(&id) = w.tagsets.get(pairs) {
            om::add(om::Counter::InternHits, 1);
            return id;
        }
        om::add(om::Counter::InternMisses, 1);
        let a: Arc<[(u32, u32)]> = Arc::from(pairs);
        let id = w.tagset_pool.len() as u32;
        w.tagset_pool.push(a.clone());
        w.tagsets.insert(a, id);
        id
    }

    /// A read view for bulk resolution: one lock acquisition for a whole
    /// shard render/materialization. Do not intern while a view is held
    /// (single-thread read→write upgrade deadlocks an `RwLock`).
    pub fn view(&self) -> View<'_> {
        View(self.inner.read().unwrap())
    }

    pub fn stats(&self) -> InternerStats {
        let g = self.inner.read().unwrap();
        let string_bytes: usize = g.pool.iter().map(|s| s.len()).sum();
        let tagset_entries: usize = g.tagset_pool.iter().map(|t| t.len()).sum();
        let arc_overhead = std::mem::size_of::<usize>() * 4;
        InternerStats {
            strings: g.pool.len(),
            tagsets: g.tagset_pool.len(),
            approx_bytes: string_bytes
                + g.pool.len() * (arc_overhead + std::mem::size_of::<Arc<str>>() * 2 + 4)
                + tagset_entries * std::mem::size_of::<(u32, u32)>()
                + g.tagset_pool.len() * (arc_overhead + std::mem::size_of::<Arc<[(u32, u32)]>>() * 2 + 4),
        }
    }
}

/// Read-locked resolver handle (see [`Interner::view`]).
pub struct View<'a>(std::sync::RwLockReadGuard<'a, Inner>);

impl View<'_> {
    pub fn string(&self, sym: u32) -> &str {
        &self.0.pool[sym as usize]
    }
    pub fn pairs(&self, tagset: u32) -> &[(u32, u32)] {
        &self.0.tagset_pool[tagset as usize]
    }
}

/// Structure-of-arrays shard body. Row `i` is
/// `(ts[i], tagset[i], field_syms/vals[start(i)..field_ends[i]])`;
/// rows are kept time-sorted exactly like the old `Vec<Point>` body,
/// and within a row the field plane is sorted by field-name string.
#[derive(Debug, Clone, Default)]
pub struct Columns {
    pub ts: Vec<i64>,
    pub tagset: Vec<u32>,
    /// End offset of row `i`'s slice of the field plane (`len == rows`;
    /// row `i` starts where row `i-1` ends).
    field_ends: Vec<u32>,
    pub field_syms: Vec<u32>,
    pub field_vals: Vec<f64>,
}

impl Columns {
    pub fn len(&self) -> usize {
        self.ts.len()
    }
    pub fn is_empty(&self) -> bool {
        self.ts.is_empty()
    }

    fn start(&self, i: usize) -> usize {
        if i == 0 {
            0
        } else {
            self.field_ends[i - 1] as usize
        }
    }

    /// Row `i`'s `(field symbols, field values)` slices (name-sorted).
    pub fn row_fields(&self, i: usize) -> (&[u32], &[f64]) {
        let a = self.start(i);
        let b = self.field_ends[i] as usize;
        (&self.field_syms[a..b], &self.field_vals[a..b])
    }

    /// Append a row (the streaming-upload fast path).
    pub fn push_row(&mut self, ts: i64, tagset: u32, syms: &[u32], vals: &[f64]) {
        debug_assert_eq!(syms.len(), vals.len());
        self.ts.push(ts);
        self.tagset.push(tagset);
        self.field_syms.extend_from_slice(syms);
        self.field_vals.extend_from_slice(vals);
        self.field_ends.push(self.field_syms.len() as u32);
    }

    /// Insert a row at `idx`, splicing the field plane (the out-of-order
    /// late-import path; `idx == len` degenerates to a push).
    pub fn insert_row(&mut self, idx: usize, ts: i64, tagset: u32, syms: &[u32], vals: &[f64]) {
        if idx == self.len() {
            self.push_row(ts, tagset, syms, vals);
            return;
        }
        debug_assert_eq!(syms.len(), vals.len());
        let at = self.start(idx);
        self.ts.insert(idx, ts);
        self.tagset.insert(idx, tagset);
        self.field_syms.splice(at..at, syms.iter().copied());
        self.field_vals.splice(at..at, vals.iter().copied());
        let n = syms.len() as u32;
        self.field_ends.insert(idx, at as u32 + n);
        for e in &mut self.field_ends[idx + 1..] {
            *e += n;
        }
    }

    /// Bulk-append another column set (rows must belong after ours).
    pub fn append_all(&mut self, other: &Columns) {
        let base = self.field_syms.len() as u32;
        self.ts.extend_from_slice(&other.ts);
        self.tagset.extend_from_slice(&other.tagset);
        self.field_syms.extend_from_slice(&other.field_syms);
        self.field_vals.extend_from_slice(&other.field_vals);
        self.field_ends.extend(other.field_ends.iter().map(|&e| e + base));
    }

    /// True when the rows are time-sorted (groups built from an in-order
    /// upload usually are — the wholesale-append fast path).
    pub fn is_time_sorted(&self) -> bool {
        self.ts.windows(2).all(|w| w[0] <= w[1])
    }

    /// Render row `i` as one line-protocol line, byte-identical to
    /// [`Point::to_line`] of the materialized row: same escaping, same
    /// (string-sorted) tag and field order, same float formatting.
    pub fn render_row(&self, i: usize, measurement: &str, view: &View<'_>, out: &mut String) {
        lp::escape_into(measurement, out);
        for &(k, v) in view.pairs(self.tagset[i]) {
            out.push(',');
            lp::escape_into(view.string(k), out);
            out.push('=');
            lp::escape_into(view.string(v), out);
        }
        out.push(' ');
        let (syms, vals) = self.row_fields(i);
        for (j, (s, v)) in syms.iter().zip(vals).enumerate() {
            if j > 0 {
                out.push(',');
            }
            lp::escape_into(view.string(*s), out);
            out.push('=');
            codec::fmt_f64(*v, out);
        }
        out.push(' ');
        codec::fmt_i64(self.ts[i], out);
    }

    /// Materialize every row as an owned [`Point`] (the public-API
    /// boundary; shards cache the result until mutated).
    pub fn to_points(&self, measurement: &str, it: &Interner) -> Vec<Point> {
        let view = it.view();
        (0..self.len())
            .map(|i| {
                let mut tags = BTreeMap::new();
                for &(k, v) in view.pairs(self.tagset[i]) {
                    tags.insert(view.string(k).to_string(), view.string(v).to_string());
                }
                let (syms, vals) = self.row_fields(i);
                let mut fields = BTreeMap::new();
                for (s, v) in syms.iter().zip(vals) {
                    fields.insert(view.string(*s).to_string(), *v);
                }
                Point {
                    measurement: measurement.to_string(),
                    tags,
                    fields,
                    ts: self.ts[i],
                }
            })
            .collect()
    }

    /// Row-convert owned points (compaction summaries, point inserts).
    pub fn from_points(pts: &[Point], it: &Interner) -> Columns {
        let mut c = Columns::default();
        for p in pts {
            let (tagset, syms, vals) = intern_point(it, p);
            c.push_row(p.ts, tagset, &syms, &vals);
        }
        c
    }
}

/// Intern one owned point's tag set and fields. `BTreeMap` iteration is
/// key-sorted, which is exactly the pair order [`Interner::tagset_of`]
/// and the field plane require.
pub fn intern_point(it: &Interner, p: &Point) -> (u32, Vec<u32>, Vec<f64>) {
    let mut pairs = Vec::with_capacity(p.tags.len());
    for (k, v) in &p.tags {
        pairs.push((it.intern(k), it.intern(v)));
    }
    let tagset = it.tagset_of(&pairs);
    let mut syms = Vec::with_capacity(p.fields.len());
    let mut vals = Vec::with_capacity(p.fields.len());
    for (k, v) in &p.fields {
        syms.push(it.intern(k));
        vals.push(*v);
    }
    (tagset, syms, vals)
}

/// One parsed-and-interned parse chunk.
pub(crate) struct Chunk {
    /// Rows grouped by `(measurement sym, shard key)`, each group in
    /// input order. Group order within a chunk is sym-ordered and NOT
    /// deterministic across runs — the merge re-keys by measurement
    /// string before touching the store.
    pub groups: Vec<((u32, i64), Columns)>,
    /// Distinct `(measurement sym, tagset id)` combos seen — the
    /// per-repo detection scopes are resolved from these.
    pub seen: Vec<(u32, u32)>,
}

/// Parse a chunk of line-protocol lines straight into interned columnar
/// groups — the serial worker body of the batched columnar ingest. Same
/// grammar, same error strings, same first-error-in-input-order
/// semantics as [`lp::parse_line`]; one reused scratch [`lp::RawLine`]
/// instead of a fresh `Point` per line.
pub(crate) fn parse_chunk(lines: &[&str], it: &Interner, span_ns: i64) -> Result<Chunk, String> {
    let mut groups: BTreeMap<(u32, i64), Columns> = BTreeMap::new();
    let mut seen: BTreeSet<(u32, u32)> = BTreeSet::new();
    let mut raw = lp::RawLine::default();
    let mut pairs: Vec<(u32, u32)> = Vec::new();
    let mut syms: Vec<u32> = Vec::new();
    let mut vals: Vec<f64> = Vec::new();
    for line in lines {
        lp::parse_line_into(line, &mut raw)?;
        let msym = it.intern(&raw.measurement);
        pairs.clear();
        for (k, v) in &raw.tags {
            pairs.push((it.intern(k), it.intern(v)));
        }
        let tagset = it.tagset_of(&pairs);
        syms.clear();
        vals.clear();
        for (k, v) in &raw.fields {
            syms.push(it.intern(k));
            vals.push(*v);
        }
        seen.insert((msym, tagset));
        let key = raw.ts.div_euclid(span_ns);
        groups
            .entry((msym, key))
            .or_default()
            .push_row(raw.ts, tagset, &syms, &vals);
    }
    Ok(Chunk {
        groups: groups.into_iter().collect(),
        seen: seen.into_iter().collect(),
    })
}

/// Parse lines into one [`Columns`] in input order (shard-file loads —
/// a shard file is a single measurement's rows, already grouped).
pub(crate) fn parse_lines_to_cols(lines: &[&str], it: &Interner) -> Result<Columns, String> {
    let mut c = Columns::default();
    let mut raw = lp::RawLine::default();
    let mut pairs: Vec<(u32, u32)> = Vec::new();
    let mut syms: Vec<u32> = Vec::new();
    let mut vals: Vec<f64> = Vec::new();
    for line in lines {
        lp::parse_line_into(line, &mut raw)?;
        pairs.clear();
        for (k, v) in &raw.tags {
            pairs.push((it.intern(k), it.intern(v)));
        }
        let tagset = it.tagset_of(&pairs);
        syms.clear();
        vals.clear();
        for (k, v) in &raw.fields {
            syms.push(it.intern(k));
            vals.push(*v);
        }
        c.push_row(raw.ts, tagset, &syms, &vals);
    }
    Ok(c)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn interner_dedups_and_roundtrips() {
        let it = Interner::default();
        let a = it.intern("node");
        let b = it.intern("icx36");
        assert_ne!(a, b);
        assert_eq!(it.intern("node"), a, "re-interning is a hit");
        assert_eq!(&*it.get(a), "node");
        assert_eq!(it.lookup("node"), Some(a));
        assert_eq!(it.lookup("never-seen"), None);
        let ts1 = it.tagset_of(&[(a, b)]);
        let ts2 = it.tagset_of(&[(a, b)]);
        assert_eq!(ts1, ts2, "equal tag sets share one id");
        assert_ne!(it.tagset_of(&[]), ts1);
        let stats = it.stats();
        assert_eq!(stats.strings, 2);
        assert_eq!(stats.tagsets, 2);
        assert!(stats.approx_bytes > 0);
    }

    #[test]
    fn columns_insert_matches_push_order() {
        let it = Interner::default();
        let t = it.tagset_of(&[]);
        let f = it.intern("v");
        let mut a = Columns::default();
        for ts in [1i64, 3, 5] {
            a.push_row(ts, t, &[f], &[ts as f64]);
        }
        // out-of-order insert lands between its neighbours
        let idx = a.ts.partition_point(|&q| q <= 2);
        a.insert_row(idx, 2, t, &[f], &[2.0]);
        a.insert_row(a.len(), 9, t, &[f], &[9.0]);
        assert_eq!(a.ts, vec![1, 2, 3, 5, 9]);
        assert!(a.is_time_sorted());
        for i in 0..a.len() {
            let (syms, vals) = a.row_fields(i);
            assert_eq!(syms, &[f]);
            assert_eq!(vals, &[a.ts[i] as f64]);
        }
    }

    #[test]
    fn render_row_matches_point_to_line() {
        let it = Interner::default();
        let pts = vec![
            Point::new("mea,su re=ment", 7)
                .tag("tag,key with=all", "va,l ue=x")
                .tag("plain", "v")
                .field("fie,ld key=f", -2.5)
                .field("g", 1e-7),
            Point::new("m\\", -1_500_000_000).tag("k\\\\", "v\\").field("f\\", 3.0),
            Point::new("m", 9).field("v", 0.1).field("w", 5e-324),
        ];
        let cols = Columns::from_points(&pts, &it);
        let view = it.view();
        for (i, p) in pts.iter().enumerate() {
            let mut line = String::new();
            cols.render_row(i, &p.measurement, &view, &mut line);
            assert_eq!(line, p.to_line(), "row {i}");
        }
    }

    #[test]
    fn to_points_roundtrips_through_from_points() {
        let it = Interner::default();
        let pts = vec![
            Point::new("m", 1).tag("s", "a").field("v", 1.5),
            Point::new("m", 2).tag("s", "b").field("v", 2.5).field("w", 0.25),
        ];
        let cols = Columns::from_points(&pts, &it);
        assert_eq!(cols.to_points("m", &it), pts);
    }

    #[test]
    fn parse_chunk_groups_by_shard_key_and_records_scopes() {
        let it = Interner::default();
        let lines = ["m,repo=r1 v=1 5", "m,repo=r1 v=2 15", "n v=3 5"];
        let chunk = parse_chunk(&lines, &it, 10).unwrap();
        assert_eq!(chunk.groups.len(), 3, "two m-shards + one n-shard");
        let total: usize = chunk.groups.iter().map(|(_, c)| c.len()).sum();
        assert_eq!(total, 3);
        assert_eq!(chunk.seen.len(), 2, "(m, repo=r1) and (n, {{}})");
    }
}
