//! Query layer over [`super::Db`]: tag filters, time ranges, group-by-tags
//! and aggregations — the subset of InfluxQL the paper's Grafana dashboards
//! use ("data ... is queried and grouped by the different parameter values
//! to connect data points with the same parameter values", §4.4).

use super::{Db, Point};
use std::collections::BTreeMap;

/// Aggregation over a field within a group.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Aggregate {
    /// The most recent value (Grafana "last") — used by the per-node
    /// "latest benchmark results" panels (Fig. 8).
    Last,
    Mean,
    Min,
    Max,
    Count,
}

/// A query against one measurement.
#[derive(Debug, Clone, Default)]
pub struct Query {
    pub measurement: String,
    pub field: String,
    /// Exact-match tag filters (AND).
    pub where_tags: BTreeMap<String, String>,
    /// Multi-value tag filter (tag IN [values]) — dashboard dropdowns with
    /// several selected entries.
    pub where_tag_in: BTreeMap<String, Vec<String>>,
    /// Inclusive time range in ns; None = unbounded.
    pub t_min: Option<i64>,
    pub t_max: Option<i64>,
    /// Tags to group the series by.
    pub group_by: Vec<String>,
}

/// One grouped series: the group's tag values and its (ts, value) points.
#[derive(Debug, Clone, PartialEq)]
pub struct GroupedSeries {
    pub group: BTreeMap<String, String>,
    pub points: Vec<(i64, f64)>,
}

impl GroupedSeries {
    pub fn aggregate(&self, agg: Aggregate) -> f64 {
        let vals: Vec<f64> = self.points.iter().map(|(_, v)| *v).collect();
        if vals.is_empty() {
            return f64::NAN;
        }
        match agg {
            Aggregate::Last => *vals.last().unwrap(),
            Aggregate::Mean => vals.iter().sum::<f64>() / vals.len() as f64,
            Aggregate::Min => vals.iter().copied().fold(f64::MAX, f64::min),
            Aggregate::Max => vals.iter().copied().fold(f64::MIN, f64::max),
            Aggregate::Count => vals.len() as f64,
        }
    }

    /// Human-readable group label, e.g. `solver=ilu,node=icx36`.
    pub fn label(&self) -> String {
        if self.group.is_empty() {
            return "all".to_string();
        }
        self.group
            .iter()
            .map(|(k, v)| format!("{k}={v}"))
            .collect::<Vec<_>>()
            .join(",")
    }
}

impl Query {
    pub fn new(measurement: &str, field: &str) -> Query {
        Query {
            measurement: measurement.to_string(),
            field: field.to_string(),
            ..Query::default()
        }
    }
    pub fn where_tag(mut self, k: &str, v: &str) -> Query {
        self.where_tags.insert(k.to_string(), v.to_string());
        self
    }
    pub fn where_tag_in(mut self, k: &str, vals: &[&str]) -> Query {
        self.where_tag_in
            .insert(k.to_string(), vals.iter().map(|s| s.to_string()).collect());
        self
    }
    pub fn range(mut self, t_min: i64, t_max: i64) -> Query {
        self.t_min = Some(t_min);
        self.t_max = Some(t_max);
        self
    }
    pub fn group_by(mut self, tags: &[&str]) -> Query {
        self.group_by = tags.iter().map(|s| s.to_string()).collect();
        self
    }

    fn matches(&self, p: &Point) -> bool {
        if let Some(t0) = self.t_min {
            if p.ts < t0 {
                return false;
            }
        }
        if let Some(t1) = self.t_max {
            if p.ts > t1 {
                return false;
            }
        }
        for (k, v) in &self.where_tags {
            if p.tags.get(k) != Some(v) {
                return false;
            }
        }
        for (k, vals) in &self.where_tag_in {
            match p.tags.get(k) {
                Some(v) if vals.contains(v) => {}
                _ => return false,
            }
        }
        p.fields.contains_key(&self.field)
    }

    /// Execute against a DB, returning one series per group (sorted by
    /// group label for stable output).
    pub fn run(&self, db: &Db) -> Vec<GroupedSeries> {
        let mut groups: BTreeMap<Vec<(String, String)>, GroupedSeries> = BTreeMap::new();
        for p in db.points(&self.measurement) {
            if !self.matches(p) {
                continue;
            }
            let key: Vec<(String, String)> = self
                .group_by
                .iter()
                .map(|t| {
                    (
                        t.clone(),
                        p.tags.get(t).cloned().unwrap_or_else(|| "<none>".to_string()),
                    )
                })
                .collect();
            let entry = groups.entry(key.clone()).or_insert_with(|| GroupedSeries {
                group: key.into_iter().collect(),
                points: Vec::new(),
            });
            entry.points.push((p.ts, p.fields[&self.field]));
        }
        groups.into_values().collect()
    }

    /// Execute and aggregate each group to a single value.
    pub fn run_agg(&self, db: &Db, agg: Aggregate) -> Vec<(String, f64)> {
        self.run(db)
            .into_iter()
            .map(|s| (s.label(), s.aggregate(agg)))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn test_db() -> Db {
        let mut db = Db::new();
        let mut add = |ts: i64, node: &str, solver: &str, tts: f64| {
            db.insert(
                Point::new("fe2ti", ts)
                    .tag("node", node)
                    .tag("solver", solver)
                    .field("tts", tts),
            );
        };
        add(1, "icx36", "ilu", 40.0);
        add(2, "icx36", "ilu", 41.0);
        add(1, "icx36", "pardiso", 60.0);
        add(2, "icx36", "pardiso", 61.0);
        add(1, "rome1", "ilu", 80.0);
        db
    }

    #[test]
    fn group_by_tag_produces_series() {
        let db = test_db();
        let series = Query::new("fe2ti", "tts")
            .where_tag("node", "icx36")
            .group_by(&["solver"])
            .run(&db);
        assert_eq!(series.len(), 2);
        assert_eq!(series[0].group["solver"], "ilu");
        assert_eq!(series[0].points, vec![(1, 40.0), (2, 41.0)]);
        assert_eq!(series[1].label(), "solver=pardiso");
    }

    #[test]
    fn aggregates() {
        let db = test_db();
        let s = &Query::new("fe2ti", "tts")
            .where_tag("node", "icx36")
            .where_tag("solver", "ilu")
            .run(&db)[0];
        assert_eq!(s.aggregate(Aggregate::Last), 41.0);
        assert_eq!(s.aggregate(Aggregate::Mean), 40.5);
        assert_eq!(s.aggregate(Aggregate::Min), 40.0);
        assert_eq!(s.aggregate(Aggregate::Max), 41.0);
        assert_eq!(s.aggregate(Aggregate::Count), 2.0);
    }

    #[test]
    fn time_range_filters() {
        let db = test_db();
        let series = Query::new("fe2ti", "tts")
            .where_tag("node", "icx36")
            .where_tag("solver", "ilu")
            .range(2, 2)
            .run(&db);
        assert_eq!(series[0].points, vec![(2, 41.0)]);
    }

    #[test]
    fn tag_in_filter() {
        let db = test_db();
        let series = Query::new("fe2ti", "tts")
            .where_tag_in("solver", &["ilu"])
            .group_by(&["node"])
            .run(&db);
        assert_eq!(series.len(), 2); // icx36 + rome1, pardiso filtered out
    }

    #[test]
    fn missing_field_or_measurement_empty() {
        let db = test_db();
        assert!(Query::new("fe2ti", "nosuch").run(&db).is_empty());
        assert!(Query::new("nosuch", "tts").run(&db).is_empty());
    }

    #[test]
    fn ungrouped_is_single_series() {
        let db = test_db();
        let series = Query::new("fe2ti", "tts").run(&db);
        assert_eq!(series.len(), 1);
        assert_eq!(series[0].label(), "all");
        assert_eq!(series[0].points.len(), 5);
    }
}
