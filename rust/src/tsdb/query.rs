//! Query layer over [`super::Db`]: tag filters, time ranges, group-by-tags
//! and aggregations — the subset of InfluxQL the paper's Grafana dashboards
//! use ("data ... is queried and grouped by the different parameter values
//! to connect data points with the same parameter values", §4.4).

use super::{Db, Point};
use std::collections::BTreeMap;

/// How many distinct *global* timestamps a filtered `tail(n)` bound scan
/// may visit per requested window slot (`n × TAIL_SCAN_SLACK` total).
/// Generous enough for 32 co-tenant repositories to interleave triggers
/// at full window depth, while keeping the worst case (filter matches
/// nothing) bounded instead of O(full history). Public because the
/// incremental detector state (`regress::state`) replicates the exact
/// cap semantics to stay byte-equivalent with this query path.
pub const TAIL_SCAN_SLACK: usize = 32;

/// Aggregation over a field within a group.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Aggregate {
    /// The most recent value (Grafana "last") — used by the per-node
    /// "latest benchmark results" panels (Fig. 8).
    Last,
    Mean,
    Min,
    Max,
    Count,
}

/// A query against one measurement.
#[derive(Debug, Clone, Default)]
pub struct Query {
    pub measurement: String,
    pub field: String,
    /// Exact-match tag filters (AND).
    pub where_tags: BTreeMap<String, String>,
    /// Multi-value tag filter (tag IN [values]) — dashboard dropdowns with
    /// several selected entries.
    pub where_tag_in: BTreeMap<String, Vec<String>>,
    /// Inclusive time range in ns; None = unbounded.
    pub t_min: Option<i64>,
    pub t_max: Option<i64>,
    /// Keep only the trailing `n` points of every group (`tail(n)`), and
    /// push the scan down to the trailing `n` distinct timestamps of the
    /// measurement — see [`Query::tail`].
    pub tail: Option<usize>,
    /// Tags to group the series by.
    pub group_by: Vec<String>,
}

/// One grouped series: the group's tag values and its (ts, value) points.
#[derive(Debug, Clone, PartialEq)]
pub struct GroupedSeries {
    pub group: BTreeMap<String, String>,
    pub points: Vec<(i64, f64)>,
}

impl GroupedSeries {
    pub fn aggregate(&self, agg: Aggregate) -> f64 {
        let vals: Vec<f64> = self.points.iter().map(|(_, v)| *v).collect();
        if vals.is_empty() {
            return f64::NAN;
        }
        match agg {
            Aggregate::Last => *vals.last().unwrap(),
            Aggregate::Mean => vals.iter().sum::<f64>() / vals.len() as f64,
            Aggregate::Min => vals.iter().copied().fold(f64::MAX, f64::min),
            Aggregate::Max => vals.iter().copied().fold(f64::MIN, f64::max),
            Aggregate::Count => vals.len() as f64,
        }
    }

    /// Human-readable group label, e.g. `solver=ilu,node=icx36`.
    pub fn label(&self) -> String {
        if self.group.is_empty() {
            return "all".to_string();
        }
        self.group
            .iter()
            .map(|(k, v)| format!("{k}={v}"))
            .collect::<Vec<_>>()
            .join(",")
    }
}

impl Query {
    pub fn new(measurement: &str, field: &str) -> Query {
        Query {
            measurement: measurement.to_string(),
            field: field.to_string(),
            ..Query::default()
        }
    }
    pub fn where_tag(mut self, k: &str, v: &str) -> Query {
        self.where_tags.insert(k.to_string(), v.to_string());
        self
    }
    pub fn where_tag_in(mut self, k: &str, vals: &[&str]) -> Query {
        self.where_tag_in
            .insert(k.to_string(), vals.iter().map(|s| s.to_string()).collect());
        self
    }
    pub fn range(mut self, t_min: i64, t_max: i64) -> Query {
        self.t_min = Some(t_min);
        self.t_max = Some(t_max);
        self
    }
    /// `tail(n)`: return only the trailing `n` points of every group.
    ///
    /// This is the per-pipeline detection pushdown: the scan is bounded to
    /// the trailing `n` *distinct* timestamps — of the whole measurement
    /// ([`Db::tail_start_ts`]) for unfiltered queries, or of the points
    /// matching the tag filters when `where_tag`/`where_tag_in` are set
    /// (so a query scoped to one repository counts that repository's
    /// trigger times, not its co-tenants'). Cost tracks the window size,
    /// not the total history length. CB uploads one point per live series
    /// per pipeline trigger, which makes the two notions line up; a
    /// series that stopped reporting more than `n` (matching) triggers
    /// ago falls outside the bound and comes back empty — i.e. "not
    /// measured anymore", which is exactly what the detector's
    /// evaluated-series bookkeeping wants. Caveat: an *unfiltered*
    /// query over a TSDB where k tenants upload at interleaved trigger
    /// times sees only ~n/k points per tenant series — scope the query
    /// (as `coordinator::check_regressions` does) when that matters.
    pub fn tail(mut self, n: usize) -> Query {
        self.tail = Some(n);
        self
    }
    pub fn group_by(mut self, tags: &[&str]) -> Query {
        self.group_by = tags.iter().map(|s| s.to_string()).collect();
        self
    }

    fn matches(&self, p: &Point) -> bool {
        if let Some(t0) = self.t_min {
            if p.ts < t0 {
                return false;
            }
        }
        if let Some(t1) = self.t_max {
            if p.ts > t1 {
                return false;
            }
        }
        for (k, v) in &self.where_tags {
            if p.tags.get(k) != Some(v) {
                return false;
            }
        }
        for (k, vals) in &self.where_tag_in {
            match p.tags.get(k) {
                Some(v) if vals.contains(v) => {}
                _ => return false,
            }
        }
        p.fields.contains_key(&self.field)
    }

    /// Execute against a DB, returning one series per group (sorted by
    /// group label for stable output). Time ranges and `tail(n)` are
    /// pushed down to the sharded storage layer: the scan is bounded by
    /// the per-shard min/max-ts index ([`Db::points_in_range`]) / the
    /// trailing distinct timestamps ([`Db::tail_start_ts`], streamed
    /// newest-shard-first) instead of materializing the full series —
    /// shards outside the window are never touched. On a manifest-loaded
    /// store "never touched" includes never *parsed*: shard bodies
    /// materialize lazily, so a bounded query against a multi-year
    /// on-disk history reads only the shard files it reaches into.
    pub fn run(&self, db: &Db) -> Vec<GroupedSeries> {
        let mut groups: BTreeMap<Vec<(String, String)>, GroupedSeries> = BTreeMap::new();
        {
            let mut add = |p: &Point| {
                if !self.matches(p) {
                    return;
                }
                let key: Vec<(String, String)> = self
                    .group_by
                    .iter()
                    .map(|t| {
                        (
                            t.clone(),
                            p.tags.get(t).cloned().unwrap_or_else(|| "<none>".to_string()),
                        )
                    })
                    .collect();
                let entry = groups.entry(key.clone()).or_insert_with(|| GroupedSeries {
                    group: key.into_iter().collect(),
                    points: Vec::new(),
                });
                entry.points.push((p.ts, p.fields[&self.field]));
            };
            if self.t_min.is_some() || self.t_max.is_some() {
                db.points_in_range(&self.measurement, self.t_min, self.t_max)
                    .for_each(&mut add);
            } else if let Some(n) = self.tail {
                let t0 = if n == 0 {
                    None
                } else if self.where_tags.is_empty() && self.where_tag_in.is_empty() {
                    db.tail_start_ts(&self.measurement, n)
                } else {
                    // with tag filters the bound must count distinct
                    // timestamps among MATCHING points only — otherwise k
                    // co-tenant repositories uploading at distinct trigger
                    // times would shrink each other's window to n/k. The
                    // walk itself is capped at n × TAIL_SCAN_SLACK distinct
                    // *global* timestamps so a filter matching nothing (or a
                    // long-stale tenant) cannot regress the scan to O(full
                    // history): tenants whose last n uploads are spread over
                    // more interleaved foreign triggers than that are treated
                    // as stale, like any series outside the tail window. The
                    // reverse walk streams shard by shard from the newest,
                    // so old shards stay untouched either way.
                    let cap = n.saturating_mul(TAIL_SCAN_SLACK);
                    let mut distinct = 0usize;
                    let mut global_distinct = 0usize;
                    let mut last_global: Option<i64> = None;
                    let mut last: Option<i64> = None;
                    let mut bound: Option<i64> = None;
                    for p in db.points_iter(&self.measurement).rev() {
                        if last_global != Some(p.ts) {
                            global_distinct += 1;
                            last_global = Some(p.ts);
                            if global_distinct > cap {
                                break;
                            }
                        }
                        if !self.matches(p) {
                            continue;
                        }
                        if last != Some(p.ts) {
                            distinct += 1;
                            last = Some(p.ts);
                            if distinct == n {
                                bound = last;
                                break;
                            }
                        }
                    }
                    bound.or(last)
                };
                if let Some(t0) = t0 {
                    db.points_in_range(&self.measurement, Some(t0), None)
                        .for_each(&mut add);
                }
            } else {
                db.points_iter(&self.measurement).for_each(&mut add);
            }
        }
        let mut out: Vec<GroupedSeries> = groups.into_values().collect();
        if let Some(n) = self.tail {
            for s in &mut out {
                if s.points.len() > n {
                    let cut = s.points.len() - n;
                    s.points.drain(..cut);
                }
            }
        }
        out
    }

    /// Execute and aggregate each group to a single value.
    pub fn run_agg(&self, db: &Db, agg: Aggregate) -> Vec<(String, f64)> {
        self.run(db)
            .into_iter()
            .map(|s| (s.label(), s.aggregate(agg)))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn test_db() -> Db {
        let mut db = Db::new();
        let mut add = |ts: i64, node: &str, solver: &str, tts: f64| {
            db.insert(
                Point::new("fe2ti", ts)
                    .tag("node", node)
                    .tag("solver", solver)
                    .field("tts", tts),
            );
        };
        add(1, "icx36", "ilu", 40.0);
        add(2, "icx36", "ilu", 41.0);
        add(1, "icx36", "pardiso", 60.0);
        add(2, "icx36", "pardiso", 61.0);
        add(1, "rome1", "ilu", 80.0);
        db
    }

    #[test]
    fn group_by_tag_produces_series() {
        let db = test_db();
        let series = Query::new("fe2ti", "tts")
            .where_tag("node", "icx36")
            .group_by(&["solver"])
            .run(&db);
        assert_eq!(series.len(), 2);
        assert_eq!(series[0].group["solver"], "ilu");
        assert_eq!(series[0].points, vec![(1, 40.0), (2, 41.0)]);
        assert_eq!(series[1].label(), "solver=pardiso");
    }

    #[test]
    fn aggregates() {
        let db = test_db();
        let s = &Query::new("fe2ti", "tts")
            .where_tag("node", "icx36")
            .where_tag("solver", "ilu")
            .run(&db)[0];
        assert_eq!(s.aggregate(Aggregate::Last), 41.0);
        assert_eq!(s.aggregate(Aggregate::Mean), 40.5);
        assert_eq!(s.aggregate(Aggregate::Min), 40.0);
        assert_eq!(s.aggregate(Aggregate::Max), 41.0);
        assert_eq!(s.aggregate(Aggregate::Count), 2.0);
    }

    #[test]
    fn time_range_filters() {
        let db = test_db();
        let series = Query::new("fe2ti", "tts")
            .where_tag("node", "icx36")
            .where_tag("solver", "ilu")
            .range(2, 2)
            .run(&db);
        assert_eq!(series[0].points, vec![(2, 41.0)]);
    }

    #[test]
    fn tail_keeps_last_n_points_per_group() {
        let db = test_db();
        let series = Query::new("fe2ti", "tts")
            .where_tag("node", "icx36")
            .group_by(&["solver"])
            .tail(1)
            .run(&db);
        assert_eq!(series.len(), 2);
        assert_eq!(series[0].points, vec![(2, 41.0)]);
        assert_eq!(series[1].points, vec![(2, 61.0)]);
        // tail larger than history: everything survives
        let series = Query::new("fe2ti", "tts")
            .where_tag("node", "icx36")
            .where_tag("solver", "ilu")
            .tail(10)
            .run(&db);
        assert_eq!(series[0].points.len(), 2);
    }

    #[test]
    fn tail_pushdown_skips_series_outside_the_trailing_window() {
        // a series that stopped reporting long ago is "not measured
        // anymore" under tail(n) — it must not come back as stale data
        let mut db = Db::new();
        db.insert(Point::new("m", 1).tag("s", "dead").field("v", 5.0));
        for ts in 10..20 {
            db.insert(Point::new("m", ts).tag("s", "live").field("v", ts as f64));
        }
        let series = Query::new("m", "v").group_by(&["s"]).tail(2).run(&db);
        assert_eq!(series.len(), 1);
        assert_eq!(series[0].group["s"], "live");
        assert_eq!(series[0].points, vec![(18, 18.0), (19, 19.0)]);
        // without tail the dead series is still there
        assert_eq!(Query::new("m", "v").group_by(&["s"]).run(&db).len(), 2);
    }

    #[test]
    fn filtered_tail_counts_matching_timestamps_only() {
        // two tenants alternate trigger timestamps; a repo-scoped tail(2)
        // must keep the repo's last 2 uploads, not last-2-overall / 2
        let mut db = Db::new();
        for (ts, repo, v) in [
            (1, "a", 10.0),
            (2, "b", 20.0),
            (3, "a", 11.0),
            (4, "b", 21.0),
            (5, "a", 12.0),
            (6, "b", 22.0),
        ] {
            db.insert(Point::new("m", ts).tag("repo", repo).field("v", v));
        }
        let series = Query::new("m", "v")
            .where_tag("repo", "a")
            .group_by(&["repo"])
            .tail(2)
            .run(&db);
        assert_eq!(series.len(), 1);
        assert_eq!(series[0].points, vec![(3, 11.0), (5, 12.0)]);
        // unfiltered tail(2) only reaches timestamps 5..6 — one point per
        // tenant — the caveat the scoped form exists for
        let series = Query::new("m", "v").group_by(&["repo"]).tail(2).run(&db);
        assert_eq!(series[0].points, vec![(5, 12.0)]);
        assert_eq!(series[1].points, vec![(6, 22.0)]);
    }

    #[test]
    fn filtered_tail_walk_is_capped_for_stale_tenants() {
        // a tenant whose only upload sits deeper than n × TAIL_SCAN_SLACK
        // interleaved foreign triggers is treated as stale instead of
        // forcing an O(full history) reverse walk
        let mut db = Db::new();
        db.insert(Point::new("m", 0).tag("repo", "old").field("v", 1.0));
        for ts in 1..200 {
            db.insert(Point::new("m", ts).tag("repo", "live").field("v", ts as f64));
        }
        let series = Query::new("m", "v")
            .where_tag("repo", "old")
            .group_by(&["repo"])
            .tail(1)
            .run(&db);
        assert!(series.is_empty(), "beyond the capped walk => stale");
        let series = Query::new("m", "v")
            .where_tag("repo", "live")
            .group_by(&["repo"])
            .tail(1)
            .run(&db);
        assert_eq!(series[0].points, vec![(199, 199.0)]);
    }

    #[test]
    fn tag_in_filter() {
        let db = test_db();
        let series = Query::new("fe2ti", "tts")
            .where_tag_in("solver", &["ilu"])
            .group_by(&["node"])
            .run(&db);
        assert_eq!(series.len(), 2); // icx36 + rome1, pardiso filtered out
    }

    #[test]
    fn missing_field_or_measurement_empty() {
        let db = test_db();
        assert!(Query::new("fe2ti", "nosuch").run(&db).is_empty());
        assert!(Query::new("nosuch", "tts").run(&db).is_empty());
    }

    #[test]
    fn ungrouped_is_single_series() {
        let db = test_db();
        let series = Query::new("fe2ti", "tts").run(&db);
        assert_eq!(series.len(), 1);
        assert_eq!(series[0].label(), "all");
        assert_eq!(series[0].points.len(), 5);
    }
}
