//! ILU(0): incomplete LU on the sparsity pattern of A — the preconditioner
//! for the paper's "inexact option" (GMRES + ILU, §2.1.3).

use super::{Csr, Work};

/// ILU(0) factors stored on A's pattern: one CSR holding L (strict lower,
/// unit diagonal implicit) and U (diagonal + upper) interleaved, as usual.
#[derive(Debug, Clone)]
pub struct Ilu0 {
    lu: Csr,
    /// position of the diagonal entry in each row of `lu`
    diag: Vec<usize>,
    pub factor_work: Work,
}

impl Ilu0 {
    /// Compute ILU(0) of `a`. Requires a structurally-present, nonzero
    /// diagonal.
    pub fn factor(a: &Csr) -> Result<Ilu0, String> {
        let n = a.n;
        let mut lu = a.clone();
        let mut w = Work::default();
        // locate diagonals
        let mut diag = vec![usize::MAX; n];
        for i in 0..n {
            for k in lu.indptr[i]..lu.indptr[i + 1] {
                if lu.indices[k] == i {
                    diag[i] = k;
                }
            }
            if diag[i] == usize::MAX {
                return Err(format!("missing diagonal in row {i}"));
            }
        }
        // IKJ variant restricted to the pattern
        for i in 1..n {
            let row_start = lu.indptr[i];
            let row_end = lu.indptr[i + 1];
            for kk in row_start..row_end {
                let k = lu.indices[kk];
                if k >= i {
                    break;
                }
                let pivot = lu.data[diag[k]];
                if pivot.abs() < 1e-300 {
                    return Err(format!("zero pivot in ILU at row {k}"));
                }
                let factor = lu.data[kk] / pivot;
                lu.data[kk] = factor;
                w.add(1.0, 24.0);
                // subtract factor * U[k, j] for j in row i's pattern, j > k
                let mut jj = kk + 1;
                let (k_start, k_end) = (diag[k] + 1, lu.indptr[k + 1]);
                let mut uk = k_start;
                while jj < row_end && uk < k_end {
                    let cj = lu.indices[jj];
                    let ck = lu.indices[uk];
                    match cj.cmp(&ck) {
                        std::cmp::Ordering::Less => jj += 1,
                        std::cmp::Ordering::Greater => uk += 1,
                        std::cmp::Ordering::Equal => {
                            lu.data[jj] -= factor * lu.data[uk];
                            w.add(2.0, 24.0);
                            jj += 1;
                            uk += 1;
                        }
                    }
                }
            }
        }
        Ok(Ilu0 {
            lu,
            diag,
            factor_work: w,
        })
    }

    /// Apply M⁻¹: solve L·U·z = r on the incomplete factors.
    pub fn apply(&self, r: &[f64], w: &mut Work) -> Vec<f64> {
        let n = self.lu.n;
        let mut z = r.to_vec();
        // forward (unit lower)
        for i in 0..n {
            let mut s = z[i];
            for k in self.lu.indptr[i]..self.diag[i] {
                s -= self.lu.data[k] * z[self.lu.indices[k]];
            }
            z[i] = s;
        }
        // backward
        for i in (0..n).rev() {
            let mut s = z[i];
            for k in self.diag[i] + 1..self.lu.indptr[i + 1] {
                s -= self.lu.data[k] * z[self.lu.indices[k]];
            }
            z[i] = s / self.lu.data[self.diag[i]];
        }
        let nnz = self.lu.nnz() as f64;
        w.add(2.0 * nnz + n as f64, 12.0 * nnz + 16.0 * n as f64);
        z
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::testmat::laplacian2d;

    #[test]
    fn ilu_exact_for_tridiagonal() {
        // for a tridiagonal matrix ILU(0) == full LU, so apply() solves exactly
        let n = 10;
        let mut t = Vec::new();
        for i in 0..n {
            t.push((i, i, 2.5));
            if i > 0 {
                t.push((i, i - 1, -1.0));
            }
            if i + 1 < n {
                t.push((i, i + 1, -1.0));
            }
        }
        let a = Csr::from_triplets(n, &t);
        let ilu = Ilu0::factor(&a).unwrap();
        let b = vec![1.0; n];
        let mut w = Work::default();
        let x = ilu.apply(&b, &mut w);
        assert!(a.residual_norm(&x, &b) < 1e-10);
    }

    #[test]
    fn ilu_is_contraction_for_laplacian() {
        let a = laplacian2d(8);
        let ilu = Ilu0::factor(&a).unwrap();
        let b = vec![1.0; a.n];
        let mut w = Work::default();
        let z = ilu.apply(&b, &mut w);
        // not exact (fill discarded) but should reduce the residual strongly
        let r0: f64 = (a.n as f64).sqrt(); // ||b|| with x=0
        let r1 = a.residual_norm(&z, &b);
        assert!(r1 < 0.7 * r0, "r1={r1} r0={r0}");
        assert!(w.flops > 0.0);
    }

    #[test]
    fn missing_diagonal_rejected() {
        let a = Csr::from_triplets(2, &[(0, 1, 1.0), (1, 0, 1.0)]);
        assert!(Ilu0::factor(&a).is_err());
    }
}
