//! Reverse Cuthill–McKee ordering: bandwidth/fill reduction before the
//! direct factorization (what PARDISO/UMFPACK's analysis phase does with
//! far fancier orderings; RCM is enough to make fill realistic).

use super::Csr;

/// Compute the RCM permutation (`perm[new] = old`) of the symmetrized
/// pattern of `a`.
pub fn rcm(a: &Csr) -> Vec<usize> {
    let n = a.n;
    // build symmetric adjacency (pattern of A + Aᵀ, no diagonal)
    let mut adj: Vec<Vec<usize>> = vec![Vec::new(); n];
    for i in 0..n {
        for &j in a.row(i).0 {
            if i != j {
                adj[i].push(j);
                adj[j].push(i);
            }
        }
    }
    for l in adj.iter_mut() {
        l.sort_unstable();
        l.dedup();
    }
    let degree: Vec<usize> = adj.iter().map(|l| l.len()).collect();

    let mut visited = vec![false; n];
    let mut order = Vec::with_capacity(n);
    // process all connected components
    loop {
        // pick unvisited vertex of minimal degree as start
        let start = match (0..n)
            .filter(|&v| !visited[v])
            .min_by_key(|&v| degree[v])
        {
            Some(s) => s,
            None => break,
        };
        // BFS, neighbors by increasing degree
        let mut queue = std::collections::VecDeque::new();
        queue.push_back(start);
        visited[start] = true;
        while let Some(v) = queue.pop_front() {
            order.push(v);
            let mut nb: Vec<usize> = adj[v].iter().copied().filter(|&u| !visited[u]).collect();
            nb.sort_by_key(|&u| degree[u]);
            for u in nb {
                visited[u] = true;
                queue.push_back(u);
            }
        }
    }
    order.reverse(); // the "reverse" in RCM
    order
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::Csr;
    use crate::util::rng::Rng;

    /// 1-D Laplacian with a random symmetric permutation applied — RCM
    /// should recover a small bandwidth.
    #[test]
    fn rcm_reduces_bandwidth() {
        let n = 64;
        let mut t = Vec::new();
        for i in 0..n {
            t.push((i, i, 2.0));
            if i > 0 {
                t.push((i, i - 1, -1.0));
            }
            if i + 1 < n {
                t.push((i, i + 1, -1.0));
            }
        }
        let a = Csr::from_triplets(n, &t);
        // scramble
        let mut perm: Vec<usize> = (0..n).collect();
        let mut rng = Rng::new(11);
        rng.shuffle(&mut perm);
        let scrambled = a.permute(&perm);
        assert!(scrambled.bandwidth() > 8, "scramble should blow up bandwidth");
        let r = rcm(&scrambled);
        let restored = scrambled.permute(&r);
        assert!(
            restored.bandwidth() <= 2,
            "rcm bandwidth = {}",
            restored.bandwidth()
        );
    }

    #[test]
    fn rcm_is_permutation_even_disconnected() {
        // two disconnected blocks
        let a = Csr::from_triplets(
            4,
            &[(0, 0, 1.0), (1, 1, 1.0), (0, 1, 0.5), (2, 2, 1.0), (3, 3, 1.0), (2, 3, 0.5)],
        );
        let mut p = rcm(&a);
        p.sort_unstable();
        assert_eq!(p, vec![0, 1, 2, 3]);
    }
}
