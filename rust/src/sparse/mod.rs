//! Sparse linear algebra substrate for the FE2TI application.
//!
//! The paper's FE2TI solves many small-to-medium sparse systems per Newton
//! step, with a choice of solver packages: MKL-PARDISO, UMFPACK (direct)
//! and GMRES+ILU (inexact, §2.1.3). None of those libraries exist here, so
//! this module implements the numerics from scratch:
//!
//! * [`csr::Csr`] — CSR storage, SpMV, triplet assembly,
//! * [`order`] — reverse Cuthill–McKee bandwidth reduction,
//! * [`lu`] — sparse LU with partial pivoting (the direct-solver core
//!   shared by our "PARDISO" and "UMFPACK" personalities; they differ in
//!   the *kernel efficiency model*, mirroring the paper's finding that
//!   UMFPACK's speed hinges on the BLAS it is linked against),
//! * [`ilu`] — ILU(0) preconditioner,
//! * [`krylov`] — GMRES(m) and CG with exact FLOP/traffic accounting.
//!
//! Every operation counts FLOPs and memory traffic into [`Work`], which the
//! likwid-like `perf` layer and the node models consume.

pub mod csr;
pub mod ilu;
pub mod krylov;
pub mod lu;
pub mod order;

pub use csr::Csr;
pub use ilu::Ilu0;
pub use krylov::{cg, gmres, KrylovResult};
pub use lu::SparseLu;

/// Exact work accounting for a linear-algebra operation.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct Work {
    pub flops: f64,
    pub bytes: f64,
}

impl Work {
    pub fn add(&mut self, flops: f64, bytes: f64) {
        self.flops += flops;
        self.bytes += bytes;
    }
    pub fn merge(&mut self, other: Work) {
        self.flops += other.flops;
        self.bytes += other.bytes;
    }
}

/// Dense vector helpers with work accounting.
pub fn dot(a: &[f64], b: &[f64], w: &mut Work) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    w.add(2.0 * a.len() as f64, 16.0 * a.len() as f64);
    a.iter().zip(b).map(|(x, y)| x * y).sum()
}

/// y += alpha * x
pub fn axpy(alpha: f64, x: &[f64], y: &mut [f64], w: &mut Work) {
    debug_assert_eq!(x.len(), y.len());
    w.add(2.0 * x.len() as f64, 24.0 * x.len() as f64);
    for (yi, xi) in y.iter_mut().zip(x) {
        *yi += alpha * xi;
    }
}

pub fn norm2(a: &[f64], w: &mut Work) -> f64 {
    w.add(2.0 * a.len() as f64, 8.0 * a.len() as f64);
    a.iter().map(|x| x * x).sum::<f64>().sqrt()
}

pub fn scale(a: &mut [f64], s: f64, w: &mut Work) {
    w.add(a.len() as f64, 16.0 * a.len() as f64);
    for x in a.iter_mut() {
        *x *= s;
    }
}

/// Shared test matrices (also used by benches).
pub mod testmat {
    use super::Csr;

    /// 2-D 5-point Laplacian on an m×m grid — SPD, well understood.
    pub fn laplacian2d(m: usize) -> Csr {
        let n = m * m;
        let idx = |i: usize, j: usize| i * m + j;
        let mut t = Vec::new();
        for i in 0..m {
            for j in 0..m {
                t.push((idx(i, j), idx(i, j), 4.0));
                if i > 0 {
                    t.push((idx(i, j), idx(i - 1, j), -1.0));
                }
                if i + 1 < m {
                    t.push((idx(i, j), idx(i + 1, j), -1.0));
                }
                if j > 0 {
                    t.push((idx(i, j), idx(i, j - 1), -1.0));
                }
                if j + 1 < m {
                    t.push((idx(i, j), idx(i, j + 1), -1.0));
                }
            }
        }
        Csr::from_triplets(n, &t)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vector_ops_and_work() {
        let mut w = Work::default();
        let a = vec![1.0, 2.0, 3.0];
        let b = vec![4.0, 5.0, 6.0];
        assert_eq!(dot(&a, &b, &mut w), 32.0);
        assert_eq!(w.flops, 6.0);
        let mut y = vec![1.0, 1.0, 1.0];
        axpy(2.0, &a, &mut y, &mut w);
        assert_eq!(y, vec![3.0, 5.0, 7.0]);
        assert!((norm2(&[3.0, 4.0], &mut w) - 5.0).abs() < 1e-15);
        let mut v = vec![2.0, 4.0];
        scale(&mut v, 0.5, &mut w);
        assert_eq!(v, vec![1.0, 2.0]);
        assert!(w.flops > 0.0 && w.bytes > 0.0);
    }
}
