//! Krylov subspace solvers: right-preconditioned GMRES(m) and CG.
//!
//! GMRES+ILU is the paper's "inexact option" for the RVE solves; its key
//! §5.1 finding is that relaxing the GMRES stopping tolerance (1e-8 →
//! 1e-4) makes it the fastest solver while Newton still converges.

use super::{dot, norm2, Csr, Ilu0, Work};

/// Result of a Krylov solve.
#[derive(Debug, Clone)]
pub struct KrylovResult {
    pub x: Vec<f64>,
    pub iters: usize,
    pub converged: bool,
    /// Final relative residual.
    pub rel_residual: f64,
    pub work: Work,
}

/// Right-preconditioned restarted GMRES(m).
pub fn gmres(
    a: &Csr,
    b: &[f64],
    precond: Option<&Ilu0>,
    tol: f64,
    restart: usize,
    max_iters: usize,
) -> KrylovResult {
    let n = a.n;
    let mut w = Work::default();
    let mut x = vec![0.0; n];
    let b_norm = norm2(b, &mut w).max(1e-300);
    let mut total_iters = 0usize;

    let apply_m = |v: &[f64], w: &mut Work| -> Vec<f64> {
        match precond {
            Some(p) => p.apply(v, w),
            None => v.to_vec(),
        }
    };

    loop {
        // r = b - A x
        let mut ax = vec![0.0; n];
        a.matvec(&x, &mut ax, &mut w);
        let r: Vec<f64> = b.iter().zip(&ax).map(|(bi, ai)| bi - ai).collect();
        w.add(n as f64, 24.0 * n as f64);
        let beta = norm2(&r, &mut w);
        if beta / b_norm < tol || total_iters >= max_iters {
            return KrylovResult {
                x,
                iters: total_iters,
                converged: beta / b_norm < tol,
                rel_residual: beta / b_norm,
                work: w,
            };
        }

        let m = restart.min(max_iters - total_iters);
        // Arnoldi basis
        let mut v: Vec<Vec<f64>> = Vec::with_capacity(m + 1);
        v.push(r.iter().map(|ri| ri / beta).collect());
        w.add(n as f64, 16.0 * n as f64);
        let mut h = vec![vec![0.0f64; m]; m + 1];
        // Givens rotations
        let mut cs = vec![0.0f64; m];
        let mut sn = vec![0.0f64; m];
        let mut g = vec![0.0f64; m + 1];
        g[0] = beta;
        let mut k_done = 0;

        for k in 0..m {
            total_iters += 1;
            // w_vec = A (M⁻¹ v_k)
            let z = apply_m(&v[k], &mut w);
            let mut w_vec = vec![0.0; n];
            a.matvec(&z, &mut w_vec, &mut w);
            // modified Gram-Schmidt
            for (j, vj) in v.iter().enumerate().take(k + 1) {
                let hjk = dot(&w_vec, vj, &mut w);
                h[j][k] = hjk;
                for (wi, vji) in w_vec.iter_mut().zip(vj) {
                    *wi -= hjk * vji;
                }
                w.add(2.0 * n as f64, 24.0 * n as f64);
            }
            let h_next = norm2(&w_vec, &mut w);
            h[k + 1][k] = h_next;

            // apply existing Givens rotations to column k
            for j in 0..k {
                let t = cs[j] * h[j][k] + sn[j] * h[j + 1][k];
                h[j + 1][k] = -sn[j] * h[j][k] + cs[j] * h[j + 1][k];
                h[j][k] = t;
            }
            // new rotation
            let denom = (h[k][k] * h[k][k] + h_next * h_next).sqrt().max(1e-300);
            cs[k] = h[k][k] / denom;
            sn[k] = h_next / denom;
            h[k][k] = denom;
            g[k + 1] = -sn[k] * g[k];
            g[k] *= cs[k];
            k_done = k + 1;

            let rel = g[k + 1].abs() / b_norm;
            if rel < tol || h_next < 1e-14 {
                break;
            }
            v.push(w_vec.iter().map(|wi| wi / h_next).collect());
            w.add(n as f64, 16.0 * n as f64);
        }

        // back-substitution for y
        let mut y = vec![0.0f64; k_done];
        for i in (0..k_done).rev() {
            let mut s = g[i];
            for j in i + 1..k_done {
                s -= h[i][j] * y[j];
            }
            y[i] = s / h[i][i];
        }
        // x += M⁻¹ (V y)
        let mut update = vec![0.0; n];
        for (j, yj) in y.iter().enumerate() {
            for (ui, vji) in update.iter_mut().zip(&v[j]) {
                *ui += yj * vji;
            }
        }
        w.add(2.0 * n as f64 * k_done as f64, 24.0 * n as f64 * k_done as f64);
        let mz = apply_m(&update, &mut w);
        for (xi, zi) in x.iter_mut().zip(&mz) {
            *xi += zi;
        }
        w.add(n as f64, 24.0 * n as f64);
    }
}

/// Conjugate gradients for SPD systems (used by the structured-grid RVE
/// path and as the reference for the JAX `rve_cg` artifact).
pub fn cg(a: &Csr, b: &[f64], tol: f64, max_iters: usize) -> KrylovResult {
    let n = a.n;
    let mut w = Work::default();
    let mut x = vec![0.0; n];
    let mut r = b.to_vec();
    let mut p = r.clone();
    let b_norm = norm2(b, &mut w).max(1e-300);
    let mut rsold = dot(&r, &r, &mut w);
    let mut iters = 0;
    while iters < max_iters {
        if rsold.sqrt() / b_norm < tol {
            break;
        }
        let mut ap = vec![0.0; n];
        a.matvec(&p, &mut ap, &mut w);
        let alpha = rsold / dot(&p, &ap, &mut w);
        for i in 0..n {
            x[i] += alpha * p[i];
            r[i] -= alpha * ap[i];
        }
        w.add(4.0 * n as f64, 48.0 * n as f64);
        let rsnew = dot(&r, &r, &mut w);
        let beta = rsnew / rsold;
        for i in 0..n {
            p[i] = r[i] + beta * p[i];
        }
        w.add(2.0 * n as f64, 24.0 * n as f64);
        rsold = rsnew;
        iters += 1;
    }
    KrylovResult {
        rel_residual: rsold.sqrt() / b_norm,
        converged: rsold.sqrt() / b_norm < tol,
        x,
        iters,
        work: w,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::testmat::laplacian2d;
    use crate::sparse::Ilu0;

    #[test]
    fn cg_solves_laplacian() {
        let a = laplacian2d(10);
        let b = vec![1.0; a.n];
        let r = cg(&a, &b, 1e-10, 1000);
        assert!(r.converged, "rel={}", r.rel_residual);
        assert!(a.residual_norm(&r.x, &b) < 1e-7);
        assert!(r.work.flops > 0.0);
    }

    #[test]
    fn gmres_unpreconditioned_solves() {
        let a = laplacian2d(8);
        let b = vec![1.0; a.n];
        let r = gmres(&a, &b, None, 1e-10, 30, 500);
        assert!(r.converged);
        assert!(a.residual_norm(&r.x, &b) < 1e-7);
    }

    #[test]
    fn ilu_preconditioning_cuts_iterations() {
        let a = laplacian2d(16);
        let b = vec![1.0; a.n];
        let plain = gmres(&a, &b, None, 1e-8, 50, 2000);
        let ilu = Ilu0::factor(&a).unwrap();
        let pre = gmres(&a, &b, Some(&ilu), 1e-8, 50, 2000);
        assert!(pre.converged && plain.converged);
        assert!(
            pre.iters * 3 < plain.iters * 2,
            "ilu iters {} vs plain {}",
            pre.iters,
            plain.iters
        );
        assert!(a.residual_norm(&pre.x, &b) < 1e-5);
    }

    #[test]
    fn relaxed_tolerance_is_cheaper() {
        // the paper's headline FE2TI finding, at the solver level
        let a = laplacian2d(16);
        let b = vec![1.0; a.n];
        let ilu = Ilu0::factor(&a).unwrap();
        let strict = gmres(&a, &b, Some(&ilu), 1e-8, 50, 2000);
        let relaxed = gmres(&a, &b, Some(&ilu), 1e-4, 50, 2000);
        assert!(relaxed.work.flops < strict.work.flops);
        assert!(relaxed.iters <= strict.iters);
        assert!(relaxed.converged);
    }

    #[test]
    fn gmres_nonsymmetric() {
        // convection-diffusion-ish: unsymmetric but solvable
        let n = 50;
        let mut t = Vec::new();
        for i in 0..n {
            t.push((i, i, 3.0));
            if i > 0 {
                t.push((i, i - 1, -1.5));
            }
            if i + 1 < n {
                t.push((i, i + 1, -0.5));
            }
        }
        let a = Csr::from_triplets(n, &t);
        let b = vec![1.0; n];
        let r = gmres(&a, &b, None, 1e-10, 20, 1000);
        assert!(r.converged);
        assert!(a.residual_norm(&r.x, &b) < 1e-7);
    }

    #[test]
    fn max_iters_respected() {
        let a = laplacian2d(16);
        let b = vec![1.0; a.n];
        let r = cg(&a, &b, 1e-14, 3);
        assert_eq!(r.iters, 3);
        assert!(!r.converged);
    }
}
