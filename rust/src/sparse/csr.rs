//! Compressed sparse row matrices with exact work accounting.

use super::Work;

/// CSR matrix.
#[derive(Debug, Clone, PartialEq)]
pub struct Csr {
    pub n: usize,
    pub indptr: Vec<usize>,
    pub indices: Vec<usize>,
    pub data: Vec<f64>,
}

impl Csr {
    /// Assemble from (row, col, value) triplets; duplicates are summed
    /// (finite-element assembly semantics).
    pub fn from_triplets(n: usize, triplets: &[(usize, usize, f64)]) -> Csr {
        let mut per_row: Vec<Vec<(usize, f64)>> = vec![Vec::new(); n];
        for &(r, c, v) in triplets {
            assert!(r < n && c < n, "triplet ({r},{c}) out of bounds for n={n}");
            per_row[r].push((c, v));
        }
        let mut indptr = Vec::with_capacity(n + 1);
        let mut indices = Vec::new();
        let mut data = Vec::new();
        indptr.push(0);
        for row in &mut per_row {
            row.sort_by_key(|&(c, _)| c);
            let mut i = 0;
            while i < row.len() {
                let c = row[i].0;
                let mut v = 0.0;
                while i < row.len() && row[i].0 == c {
                    v += row[i].1;
                    i += 1;
                }
                if v != 0.0 || c == usize::MAX {
                    indices.push(c);
                    data.push(v);
                }
            }
            indptr.push(indices.len());
        }
        Csr {
            n,
            indptr,
            indices,
            data,
        }
    }

    pub fn identity(n: usize) -> Csr {
        Csr {
            n,
            indptr: (0..=n).collect(),
            indices: (0..n).collect(),
            data: vec![1.0; n],
        }
    }

    pub fn nnz(&self) -> usize {
        self.data.len()
    }

    /// Row accessor: (col indices, values).
    pub fn row(&self, i: usize) -> (&[usize], &[f64]) {
        let (a, b) = (self.indptr[i], self.indptr[i + 1]);
        (&self.indices[a..b], &self.data[a..b])
    }

    pub fn get(&self, i: usize, j: usize) -> f64 {
        let (cols, vals) = self.row(i);
        match cols.binary_search(&j) {
            Ok(k) => vals[k],
            Err(_) => 0.0,
        }
    }

    /// y = A·x (counts 2·nnz flops, nnz·(8+4)+rows·16 bytes of traffic).
    pub fn matvec(&self, x: &[f64], y: &mut [f64], w: &mut Work) {
        debug_assert_eq!(x.len(), self.n);
        debug_assert_eq!(y.len(), self.n);
        for i in 0..self.n {
            let mut s = 0.0;
            for k in self.indptr[i]..self.indptr[i + 1] {
                s += self.data[k] * x[self.indices[k]];
            }
            y[i] = s;
        }
        w.add(
            2.0 * self.nnz() as f64,
            12.0 * self.nnz() as f64 + 16.0 * self.n as f64,
        );
    }

    /// Symmetric permutation B = P·A·Pᵀ where `perm[new] = old`.
    pub fn permute(&self, perm: &[usize]) -> Csr {
        let n = self.n;
        let mut inv = vec![0usize; n];
        for (new, &old) in perm.iter().enumerate() {
            inv[old] = new;
        }
        let mut triplets = Vec::with_capacity(self.nnz());
        for i_new in 0..n {
            let i_old = perm[i_new];
            let (cols, vals) = self.row(i_old);
            for (c, v) in cols.iter().zip(vals) {
                triplets.push((i_new, inv[*c], *v));
            }
        }
        Csr::from_triplets(n, &triplets)
    }

    /// Bandwidth: max |i - j| over structural nonzeros.
    pub fn bandwidth(&self) -> usize {
        let mut bw = 0usize;
        for i in 0..self.n {
            for &j in self.row(i).0 {
                bw = bw.max(i.abs_diff(j));
            }
        }
        bw
    }

    /// Dense residual check helper: ||A·x - b||₂.
    pub fn residual_norm(&self, x: &[f64], b: &[f64]) -> f64 {
        let mut y = vec![0.0; self.n];
        let mut w = Work::default();
        self.matvec(x, &mut y, &mut w);
        y.iter()
            .zip(b)
            .map(|(yi, bi)| (yi - bi) * (yi - bi))
            .sum::<f64>()
            .sqrt()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small() -> Csr {
        // [ 4 1 0 ]
        // [ 1 3 1 ]
        // [ 0 1 2 ]
        Csr::from_triplets(
            3,
            &[
                (0, 0, 4.0),
                (0, 1, 1.0),
                (1, 0, 1.0),
                (1, 1, 3.0),
                (1, 2, 1.0),
                (2, 1, 1.0),
                (2, 2, 2.0),
            ],
        )
    }

    #[test]
    fn triplet_assembly_sums_duplicates() {
        let a = Csr::from_triplets(2, &[(0, 0, 1.0), (0, 0, 2.0), (1, 1, 1.0)]);
        assert_eq!(a.get(0, 0), 3.0);
        assert_eq!(a.nnz(), 2);
    }

    #[test]
    fn matvec_correct_and_counts() {
        let a = small();
        let mut y = vec![0.0; 3];
        let mut w = Work::default();
        a.matvec(&[1.0, 2.0, 3.0], &mut y, &mut w);
        assert_eq!(y, vec![6.0, 10.0, 8.0]);
        assert_eq!(w.flops, 2.0 * a.nnz() as f64);
        assert!(w.bytes > 0.0);
    }

    #[test]
    fn get_and_row() {
        let a = small();
        assert_eq!(a.get(1, 2), 1.0);
        assert_eq!(a.get(0, 2), 0.0);
        let (cols, vals) = a.row(1);
        assert_eq!(cols, &[0, 1, 2]);
        assert_eq!(vals, &[1.0, 3.0, 1.0]);
    }

    #[test]
    fn permute_roundtrip() {
        let a = small();
        let perm = vec![2, 0, 1]; // new->old
        let b = a.permute(&perm);
        // b[0,0] should equal a[2,2]
        assert_eq!(b.get(0, 0), a.get(2, 2));
        // matvec consistency: permute x accordingly
        let x = [1.0, 2.0, 3.0];
        let mut w = Work::default();
        let mut y_a = vec![0.0; 3];
        a.matvec(&x, &mut y_a, &mut w);
        let xp: Vec<f64> = perm.iter().map(|&o| x[o]).collect();
        let mut y_b = vec![0.0; 3];
        b.matvec(&xp, &mut y_b, &mut w);
        for (new, &old) in perm.iter().enumerate() {
            assert!((y_b[new] - y_a[old]).abs() < 1e-14);
        }
    }

    #[test]
    fn identity_and_bandwidth() {
        let i = Csr::identity(4);
        assert_eq!(i.bandwidth(), 0);
        assert_eq!(small().bandwidth(), 1);
        let mut y = vec![0.0; 4];
        let mut w = Work::default();
        i.matvec(&[1.0, 2.0, 3.0, 4.0], &mut y, &mut w);
        assert_eq!(y, vec![1.0, 2.0, 3.0, 4.0]);
    }

    #[test]
    fn residual_norm_zero_for_exact() {
        let a = small();
        let x = [1.0, 1.0, 1.0];
        let b = [5.0, 5.0, 3.0];
        assert!(a.residual_norm(&x, &b) < 1e-14);
    }

    #[test]
    #[should_panic]
    fn out_of_bounds_triplet_panics() {
        Csr::from_triplets(2, &[(0, 5, 1.0)]);
    }
}
