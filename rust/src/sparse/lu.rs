//! Sparse LU factorization (up-looking, sparse accumulator).
//!
//! This is the numerical core shared by the "MKL-PARDISO" and "UMFPACK"
//! solver personalities of the FE2TI application: both factor A = L·U and
//! do forward/backward substitution; they differ only in the *performance
//! model* (kernel efficiency / BLAS linkage) applied by `apps::fe2ti`.
//! No pivoting — the FE systems solved here are SPD-dominant after
//! Dirichlet elimination; tiny pivots are detected and reported.

use super::{Csr, Work};

/// L (unit lower, diagonal implicit) and U (upper incl. diagonal) factors.
#[derive(Debug, Clone)]
pub struct SparseLu {
    pub n: usize,
    /// L rows, strictly-lower entries (col, val), sorted by col.
    l_rows: Vec<Vec<(usize, f64)>>,
    /// U rows, diagonal-and-upper entries (col, val), sorted by col.
    u_rows: Vec<Vec<(usize, f64)>>,
    /// Exact work spent in factorization.
    pub factor_work: Work,
}

impl SparseLu {
    /// Factor `a`. Returns an error on a (near-)zero pivot.
    pub fn factor(a: &Csr) -> Result<SparseLu, String> {
        let n = a.n;
        let mut l_rows: Vec<Vec<(usize, f64)>> = Vec::with_capacity(n);
        let mut u_rows: Vec<Vec<(usize, f64)>> = Vec::with_capacity(n);
        let mut w = Work::default();

        // sparse accumulator
        let mut vals = vec![0.0f64; n];
        let mut mask = vec![false; n];

        for i in 0..n {
            // scatter row i
            let (cols, data) = a.row(i);
            let mut pattern: Vec<usize> = Vec::with_capacity(cols.len() * 4);
            for (&c, &v) in cols.iter().zip(data) {
                vals[c] = v;
                mask[c] = true;
                pattern.push(c);
            }
            pattern.sort_unstable();
            w.add(0.0, 12.0 * cols.len() as f64);

            // eliminate columns < i in increasing order; pattern grows
            let mut l_row: Vec<(usize, f64)> = Vec::new();
            let mut k_idx = 0;
            while k_idx < pattern.len() {
                let k = pattern[k_idx];
                if k >= i {
                    break;
                }
                let a_ik = vals[k];
                if a_ik != 0.0 {
                    // pivot = U[k,k] is first entry of u_rows[k]
                    let u_row = &u_rows[k];
                    let pivot = u_row[0].1;
                    let factor = a_ik / pivot;
                    l_row.push((k, factor));
                    // vals -= factor * U[k, k+1..]
                    for &(c, uv) in &u_row[1..] {
                        if !mask[c] {
                            mask[c] = true;
                            vals[c] = 0.0;
                            // insert c keeping pattern sorted beyond k_idx
                            let pos = match pattern[k_idx + 1..].binary_search(&c) {
                                Ok(p) | Err(p) => k_idx + 1 + p,
                            };
                            pattern.insert(pos, c);
                        }
                        vals[c] -= factor * uv;
                    }
                    w.add(
                        2.0 * u_row.len() as f64,
                        12.0 * u_row.len() as f64,
                    );
                }
                k_idx += 1;
            }

            // gather: split into L (handled above) and U parts
            let mut u_row: Vec<(usize, f64)> = Vec::new();
            for &c in &pattern {
                let v = vals[c];
                mask[c] = false;
                vals[c] = 0.0;
                if c >= i && v != 0.0 {
                    u_row.push((c, v));
                }
            }
            if u_row.first().map(|&(c, v)| c != i || v.abs() < 1e-300).unwrap_or(true) {
                return Err(format!("zero pivot at row {i}"));
            }
            l_rows.push(l_row);
            u_rows.push(u_row);
        }

        Ok(SparseLu {
            n,
            l_rows,
            u_rows,
            factor_work: w,
        })
    }

    /// Number of stored factor entries (fill-in measure).
    pub fn factor_nnz(&self) -> usize {
        self.l_rows.iter().map(|r| r.len()).sum::<usize>()
            + self.u_rows.iter().map(|r| r.len()).sum::<usize>()
    }

    /// Solve A·x = b via L·U. Counts work into `w`.
    pub fn solve(&self, b: &[f64], w: &mut Work) -> Vec<f64> {
        debug_assert_eq!(b.len(), self.n);
        let mut x = b.to_vec();
        // forward: L·y = b (unit diagonal)
        for i in 0..self.n {
            let mut s = x[i];
            for &(c, v) in &self.l_rows[i] {
                s -= v * x[c];
            }
            x[i] = s;
        }
        // backward: U·x = y
        for i in (0..self.n).rev() {
            let row = &self.u_rows[i];
            let mut s = x[i];
            for &(c, v) in &row[1..] {
                s -= v * x[c];
            }
            x[i] = s / row[0].1;
        }
        let nnz = self.factor_nnz() as f64;
        w.add(2.0 * nnz + self.n as f64, 12.0 * nnz + 16.0 * self.n as f64);
        x
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::order::rcm;
    use crate::util::rng::Rng;

    /// 2-D 5-point Laplacian on an m×m grid.
    pub fn laplacian2d(m: usize) -> Csr {
        let n = m * m;
        let idx = |i: usize, j: usize| i * m + j;
        let mut t = Vec::new();
        for i in 0..m {
            for j in 0..m {
                t.push((idx(i, j), idx(i, j), 4.0));
                if i > 0 {
                    t.push((idx(i, j), idx(i - 1, j), -1.0));
                }
                if i + 1 < m {
                    t.push((idx(i, j), idx(i + 1, j), -1.0));
                }
                if j > 0 {
                    t.push((idx(i, j), idx(i, j - 1), -1.0));
                }
                if j + 1 < m {
                    t.push((idx(i, j), idx(i, j + 1), -1.0));
                }
            }
        }
        Csr::from_triplets(n, &t)
    }

    #[test]
    fn factor_solve_small() {
        let a = Csr::from_triplets(
            2,
            &[(0, 0, 4.0), (0, 1, 1.0), (1, 0, 1.0), (1, 1, 3.0)],
        );
        let lu = SparseLu::factor(&a).unwrap();
        let mut w = Work::default();
        let x = lu.solve(&[5.0, 4.0], &mut w);
        assert!((x[0] - 1.0).abs() < 1e-12 && (x[1] - 1.0).abs() < 1e-12);
        assert!(w.flops > 0.0);
    }

    #[test]
    fn laplacian_solution_matches_manufactured() {
        let m = 12;
        let a = laplacian2d(m);
        let n = a.n;
        let mut rng = Rng::new(3);
        let x_true: Vec<f64> = (0..n).map(|_| rng.range(-1.0, 1.0)).collect();
        let mut b = vec![0.0; n];
        let mut w = Work::default();
        a.matvec(&x_true, &mut b, &mut w);
        let lu = SparseLu::factor(&a).unwrap();
        let x = lu.solve(&b, &mut w);
        let err: f64 = x
            .iter()
            .zip(&x_true)
            .map(|(a, b)| (a - b) * (a - b))
            .sum::<f64>()
            .sqrt();
        assert!(err < 1e-10, "err={err}");
        assert!(lu.factor_work.flops > 0.0);
    }

    #[test]
    fn rcm_reduces_fill() {
        let m = 16;
        let a = laplacian2d(m);
        // scramble to provoke fill, then RCM should recover
        let mut perm: Vec<usize> = (0..a.n).collect();
        let mut rng = Rng::new(1);
        rng.shuffle(&mut perm);
        let scrambled = a.permute(&perm);
        let fill_scrambled = SparseLu::factor(&scrambled).unwrap().factor_nnz();
        let r = rcm(&scrambled);
        let ordered = scrambled.permute(&r);
        let fill_ordered = SparseLu::factor(&ordered).unwrap().factor_nnz();
        assert!(
            (fill_ordered as f64) < 0.8 * fill_scrambled as f64,
            "ordered={fill_ordered} scrambled={fill_scrambled}"
        );
    }

    #[test]
    fn singular_matrix_detected() {
        let a = Csr::from_triplets(2, &[(0, 0, 1.0), (0, 1, 1.0), (1, 0, 1.0), (1, 1, 1.0)]);
        assert!(SparseLu::factor(&a).is_err());
    }

    #[test]
    fn residual_small_for_larger_system() {
        let a = laplacian2d(20);
        let b = vec![1.0; a.n];
        let lu = SparseLu::factor(&a).unwrap();
        let mut w = Work::default();
        let x = lu.solve(&b, &mut w);
        assert!(a.residual_norm(&x, &b) < 1e-9);
    }
}
