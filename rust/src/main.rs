//! `cbench` launcher — CLI entry point for the continuous-benchmarking
//! infrastructure.

use cbench::cluster::microbench::{run_host_microbench, MicrobenchKind};
use cbench::cluster::nodes::{catalogue, node};
use cbench::coordinator::campaign::{self, CampaignConfig};
use cbench::coordinator::{fe2ti_pipeline, walberla_pipeline, BenchConfig, CbSystem, PreparedJob};
use cbench::dashboard::{
    campaign_dashboard, fe2ti_dashboard, self_observability_dashboard, walberla_dashboard,
};
use cbench::regress::{bisect_pipeline, AlertBook, AlertState, BisectReport, Detector};
use cbench::report;
use cbench::tsdb::{Aggregate, Db, Query};
use cbench::util::cli::Args;
use cbench::util::table::Table;
use cbench::vcs::{PushEvent, Repository};
use std::path::{Path, PathBuf};

fn main() {
    // die quietly when piped into `head` etc. instead of panicking
    #[cfg(unix)]
    unsafe {
        libc::signal(libc::SIGPIPE, libc::SIG_DFL);
    }
    let argv: Vec<String> = std::env::args().skip(1).collect();
    match cbench_main(argv) {
        Ok(()) => {}
        Err(e) => {
            eprintln!("error: {e:#}");
            std::process::exit(1);
        }
    }
}

fn cbench_main(argv: Vec<String>) -> anyhow::Result<()> {
    let cmd = argv.first().map(|s| s.as_str()).unwrap_or("help");
    let args = Args::parse(argv.iter().skip(1).cloned());
    // process-wide worker count for every par:: fan-out (collect/detect,
    // shard I/O, batched lp parsing). 0 = one worker per available core.
    // Results are byte-identical for any value — this knob trades only
    // wall-clock.
    cbench::par::set_threads(args.get_usize("threads", 0));
    match cmd {
        "help" | "--help" | "-h" => {
            println!("{HELP}");
            Ok(())
        }
        "report" => cmd_report(&args),
        "pipeline" => cmd_pipeline(&args),
        "campaign" => cmd_campaign(&args),
        "cluster" => cmd_cluster(&args),
        "microbench" => cmd_microbench(&args),
        "dashboard" => cmd_dashboard(&args),
        "artifacts" => cmd_artifacts(&args),
        "regress" => cmd_regress(&args),
        "trace" => cmd_trace(&args),
        "tsdb" => cmd_tsdb(&args),
        "serve" => cmd_serve(&args),
        "loadgen" => cmd_loadgen(&args),
        other => anyhow::bail!("unknown command `{other}` — see `cbench help`"),
    }
}

/// `cbench report <id>|all [--out DIR]` — regenerate paper tables/figures.
fn cmd_report(args: &Args) -> anyhow::Result<()> {
    let out = args.get("out").map(PathBuf::from);
    let ids: Vec<String> = match args.positional.first().map(|s| s.as_str()) {
        Some("all") | None => report::all_reports().iter().map(|s| s.to_string()).collect(),
        Some(id) => vec![id.to_string()],
    };
    for id in ids {
        println!("{}", report::run_report(&id, out.as_deref())?);
        println!();
    }
    Ok(())
}

/// Deterministic simulated commit history shared by `cbench pipeline` and
/// `cbench regress bisect`: commit ids depend only on (author, message,
/// parent, tree), so rebuilding with the same arguments reproduces the
/// exact chain the pipeline benchmarked. `inject_at` (1-based, 0 = none)
/// plants the waLBerla kernel-generation regression by committing a
/// `benchmark.cfg` with `lbm_efficiency_penalty = <penalty>` — the knob
/// the pipeline's whole purpose is to catch (paper §1, §3).
fn simulated_history(
    which: &str,
    commits: usize,
    inject_at: usize,
    penalty: f64,
) -> (Repository, Vec<PushEvent>) {
    let mut repo = Repository::new(which);
    let mut events = Vec::with_capacity(commits);
    for i in 0..commits {
        let ev = if inject_at > 0 && i + 1 == inject_at {
            repo.commit_change(
                "master",
                "dev",
                &format!("change #{i} (kernel regen, perf bug)"),
                i as f64 * 60.0,
                "benchmark.cfg",
                &format!("lbm_efficiency_penalty = {penalty}\n"),
            )
        } else {
            repo.commit_change(
                "master",
                "dev",
                &format!("change #{i}"),
                i as f64 * 60.0,
                "src/kernel.c",
                &format!("// rev {i}\n"),
            )
        };
        events.push(ev);
    }
    (repo, events)
}

/// Shared `--save-tsdb` / `--save-alerts` / `--save-state` resume logic
/// of `cbench pipeline` and `cbench campaign`: the TSDB accumulates
/// across runs (new pipelines append after the saved history — alerts
/// resolve only on real evidence; a manifest store loads its shard index
/// eagerly and shard bodies lazily, so resuming on a multi-year history
/// parses nothing old), the alert lifecycle survives (acknowledgements,
/// bisection results, resolution history; ids keep counting,
/// fingerprints deduplicate), and the incremental detector state carries
/// its per-series windows so the first check of this run does not
/// re-derive them (stale/mismatched state rebuilds itself, bounded). The
/// loaded book references a previous process's datastore, and ids are
/// per-store, so they are detached before this run archives anything.
/// Returns `(tsdb_path, alerts_path, state_path)` for the closing save.
fn load_persisted_state<'a>(
    cb: &mut CbSystem,
    args: &'a Args,
) -> anyhow::Result<(&'a str, &'a str, &'a str)> {
    let tsdb_path = args.get_or("save-tsdb", "cbench_tsdb.lp");
    if Path::new(tsdb_path).exists() {
        cb.adopt_db(Db::load(Path::new(tsdb_path))?);
        println!("resuming TSDB {tsdb_path} ({} points)", cb.db.len());
    }
    let alerts_path = args.get_or("save-alerts", "cbench_alerts.json");
    cb.alerts = AlertBook::load(Path::new(alerts_path))?;
    cb.alerts.detach_store();
    let state_path = args.get_or("save-state", "cbench_detector_state.json");
    cb.det_state = cbench::regress::DetectorState::load(Path::new(state_path))?;
    // `--shard-cache N`: cap loaded shard bodies — cold shards evict (LRU)
    // after each insert and lazily re-materialize from their files on the
    // next read, bounding resident memory on multi-year histories
    if let Some(cap) = args.get("shard-cache") {
        let cap: usize = cap
            .parse()
            .map_err(|_| anyhow::anyhow!("--shard-cache `{cap}`: expected a shard count"))?;
        cb.db.set_body_cap(Some(cap));
    }
    Ok((tsdb_path, alerts_path, state_path))
}

/// Parse the shared `--detect incremental|requery` flag.
fn parse_detect_mode(args: &Args) -> anyhow::Result<bool> {
    match args.get_or("detect", "incremental") {
        "incremental" | "inc" => Ok(true),
        "requery" | "full" => Ok(false),
        other => anyhow::bail!("--detect `{other}`: expected incremental|requery"),
    }
}

fn pipeline_jobs_for(which: &str, repo: &Repository, commit_id: &str) -> Vec<PreparedJob> {
    match which {
        "fe2ti" => fe2ti_pipeline::fe2ti_pipeline_jobs(repo, commit_id),
        _ => walberla_pipeline::walberla_pipeline_jobs(repo, commit_id),
    }
}

/// `cbench pipeline <fe2ti|walberla|describe> [--commits N]
/// [--inject-regression K] [--penalty P]` — run the CB pipeline end to
/// end on simulated commits; state persists to `--save-tsdb` /
/// `--save-alerts` (defaults `cbench_tsdb.lp` / `cbench_alerts.json`) so
/// `cbench regress` can pick up where the pipeline left off.
fn cmd_pipeline(args: &Args) -> anyhow::Result<()> {
    let which = args.positional.first().map(|s| s.as_str()).unwrap_or("describe");
    if which == "describe" {
        println!("{PIPELINE_DESCRIPTION}");
        return Ok(());
    }
    anyhow::ensure!(
        which == "fe2ti" || which == "walberla",
        "unknown pipeline `{which}` (fe2ti|walberla)"
    );
    let commits = args.get_usize("commits", 1);
    let inject_at = args.get_usize("inject-regression", 0);
    let penalty = args.get_f64("penalty", 0.15);
    if inject_at > commits {
        anyhow::bail!("--inject-regression {inject_at} is past the last commit ({commits})");
    }
    let mut cb = CbSystem::new();
    let (tsdb_path, alerts_path, state_path) = load_persisted_state(&mut cb, args)?;
    cb.set_incremental_detection(parse_detect_mode(args)?);
    let (repo, events) = simulated_history(which, commits, inject_at, penalty);
    let measurement = if which == "fe2ti" { "fe2ti" } else { "lbm" };
    for ev in &events {
        let jobs = pipeline_jobs_for(which, &repo, &ev.commit_id);
        // the commit's benchmark.cfg may tune its own detection
        // (regress.<policy>.<knob> overrides)
        cb.apply_regress_config(&BenchConfig::from_commit(&repo, &ev.commit_id));
        let r = cb.execute_pipeline(ev, which == "walberla", jobs, measurement)?;
        println!(
            "pipeline #{} commit {} jobs={} completed={} failed={} points={} records={} cluster-time={}{}",
            r.pipeline_id,
            &r.commit_id[..8],
            r.jobs_total,
            r.jobs_completed,
            r.jobs_failed,
            r.points_uploaded,
            r.records_created,
            cbench::util::fmt_secs(r.duration),
            if r.regressions.opened > 0 {
                format!("  !! {} regression alert(s) OPENED", r.regressions.opened)
            } else if r.regressions.auto_resolved > 0 {
                format!("  ok: {} alert(s) auto-resolved", r.regressions.auto_resolved)
            } else {
                String::new()
            },
        );
    }
    let rep = cb.db.save_report(Path::new(tsdb_path))?;
    println!(
        "tsdb saved to {tsdb_path} ({} points; {} shard file(s) rewritten, {} kept)",
        cb.db.len(),
        rep.shards_written,
        rep.shards_kept
    );
    cb.alerts.save(Path::new(alerts_path))?;
    cb.det_state.save(Path::new(state_path))?;
    println!(
        "alerts saved to {alerts_path} ({} active) — inspect with `cbench regress alerts`; \
         detector state -> {state_path}",
        cb.alerts.active().len()
    );
    if let Some(tp) = args.get("save-trace") {
        cb.trace.save(Path::new(tp))?;
        println!(
            "trace saved to {tp} ({} spans) — `cbench trace show --trace {tp}`",
            cb.trace.len()
        );
    }
    // render the project dashboard, annotated with open alerts
    let dash = if which == "fe2ti" {
        fe2ti_dashboard()
    } else {
        walberla_dashboard()
    };
    println!("\n{}", dash.render_text_with_alerts(&cb.db, &cb.alerts.active()));
    Ok(())
}

/// Parse `--drain NODE@FROM..TO[,NODE@FROM..TO...]` (simulated seconds)
/// into maintenance windows. TO must be finite: a campaign never resumes
/// nodes, so an open-ended drain would strand that node's jobs forever.
fn parse_drain_specs(spec: Option<&str>) -> anyhow::Result<Vec<(String, f64, f64)>> {
    let mut out = Vec::new();
    let Some(spec) = spec else { return Ok(out) };
    for part in spec.split(',').filter(|s| !s.trim().is_empty()) {
        let part = part.trim();
        let (host, range) = part
            .split_once('@')
            .ok_or_else(|| anyhow::anyhow!("--drain `{part}`: expected NODE@FROM..TO"))?;
        let (from, to) = range
            .split_once("..")
            .ok_or_else(|| anyhow::anyhow!("--drain `{part}`: expected NODE@FROM..TO (seconds)"))?;
        let from: f64 = from
            .trim()
            .parse()
            .map_err(|_| anyhow::anyhow!("--drain `{part}`: FROM is not a number"))?;
        let to: f64 = to.trim().parse().map_err(|_| {
            anyhow::anyhow!(
                "--drain `{part}`: TO is not a number (campaigns need a finite \
                 resume time — nothing would ever start on the node again)"
            )
        })?;
        anyhow::ensure!(from < to, "--drain `{part}`: FROM must be below TO");
        anyhow::ensure!(to.is_finite(), "--drain `{part}`: TO must be finite");
        out.push((host.trim().to_string(), from, to));
    }
    Ok(out)
}

/// `cbench campaign [--repos N] [--pushes M] [--inject-regression K]
/// [--penalty P] [--seed S] [--backfill on|off] [--drain NODE@FROM..TO]
/// [--collect streaming|batch] [--save-tsdb FILE] [--save-alerts FILE]` —
/// the multi-repo coordinator: N repositories (alternating waLBerla /
/// FE2TI matrices) each push M commits; every resulting pipeline is
/// submitted onto ONE event-driven scheduler so their jobs interleave on
/// the shared Testcluster. Under `--collect streaming` (the default)
/// each pipeline's results are parsed, uploaded and fed to regression
/// detection at its completion instant on the simulated clock — the
/// first upload lands while the roster is still running; `--collect
/// batch` restores drain-then-collect for A/B latency comparisons (same
/// TSDB benchmark contents, alert set and timeline, later uploads).
/// Reports the overlapped simulated makespan against the sequential
/// back-to-back baseline plus the first-upload time and worst alert SLA.
/// `--drain` opens scontrol-style maintenance windows; `--backfill off`
/// disables the timelimit-aware gap filling (for A/B makespan runs).
/// `--select change-aware` runs only the jobs a push's changed paths can
/// affect and carries the rest forward as `carried=1` points (see
/// `select::`); the default `full` runs every job on every push.
fn cmd_campaign(args: &Args) -> anyhow::Result<()> {
    let repos = args.get_usize("repos", 2);
    let pushes = args.get_usize("pushes", 2);
    let inject_at = args.get_usize("inject-regression", 0);
    let penalty = args.get_f64("penalty", 0.15);
    let seed = args.get_usize("seed", 42) as u64;
    anyhow::ensure!(repos >= 1, "--repos must be at least 1");
    let backfill = match args.get_or("backfill", "on") {
        "on" | "true" | "1" => true,
        "off" | "false" | "0" => false,
        other => anyhow::bail!("--backfill `{other}`: expected on|off"),
    };
    let streaming = match args.get_or("collect", "streaming") {
        "streaming" | "stream" => true,
        "batch" => false,
        other => anyhow::bail!("--collect `{other}`: expected streaming|batch"),
    };
    let drains = parse_drain_specs(args.get("drain"))?;
    let incremental = parse_detect_mode(args)?;
    let select = cbench::select::SelectMode::parse(args.get_or("select", "full"))
        .ok_or_else(|| {
            anyhow::anyhow!(
                "--select `{}`: expected change-aware|full",
                args.get_or("select", "full")
            )
        })?;
    let self_metrics = match args.get_or("self-metrics", "off") {
        "on" | "true" | "1" => true,
        "off" | "false" | "0" => false,
        other => anyhow::bail!("--self-metrics `{other}`: expected on|off"),
    };
    let self_slowdown = args.get_f64("self-slowdown", 1.0);
    anyhow::ensure!(self_slowdown > 0.0, "--self-slowdown must be positive");

    let mut cb = CbSystem::new();
    let (tsdb_path, alerts_path, state_path) = load_persisted_state(&mut cb, args)?;
    cb.set_self_metrics(self_metrics);
    cb.set_self_slowdown(self_slowdown);

    let mut projects = campaign::default_projects(repos);
    let cfg = CampaignConfig {
        pushes,
        inject_at,
        penalty,
        seed,
        backfill,
        drains,
        streaming,
        incremental,
        select,
    };
    for (host, from, until) in &cfg.drains {
        println!("maintenance: {host} drained over [{from:.0}..{until:.0}) (simulated s)");
    }
    let out = campaign::run_campaign(&mut cb, &mut projects, &cfg)?;

    for r in &out.reports {
        println!(
            "pipeline #{:<3} {:<12} commit {} jobs={:<3} failed={} backfilled={} points={:<3} wall={} standalone={}{}",
            r.pipeline_id,
            r.repo,
            &r.commit_id[..8.min(r.commit_id.len())],
            r.jobs_total,
            r.jobs_failed,
            r.jobs_backfilled,
            r.points_uploaded,
            cbench::util::fmt_secs(r.duration),
            cbench::util::fmt_secs(r.standalone_duration),
            if r.regressions.opened > 0 {
                format!("  !! {} regression alert(s) OPENED", r.regressions.opened)
            } else {
                String::new()
            },
        );
    }

    let speedup = out.overlap_speedup();
    println!(
        "\ncampaign: {repos} repositories x {pushes} push(es) = {} pipelines, {} jobs on one Testcluster",
        out.reports.len(),
        out.total_jobs()
    );
    println!(
        "simulated makespan (overlapped):  {}",
        cbench::util::fmt_secs(out.makespan)
    );
    println!(
        "sequential back-to-back baseline: {}",
        cbench::util::fmt_secs(out.sequential_baseline)
    );
    println!("overlap speedup: {speedup:.2}x");
    if out.makespan < out.sequential_baseline {
        println!("overlap: makespan BELOW sequential baseline");
    } else {
        println!("overlap: no improvement over sequential baseline");
    }
    println!(
        "collect mode: {} — first upload at {} cluster time (makespan {})",
        if out.streaming { "streaming" } else { "batch" },
        cbench::util::fmt_secs(out.first_upload_at()),
        cbench::util::fmt_secs(out.makespan)
    );
    if let Some(sla) = out.worst_alert_sla() {
        println!(
            "worst alert SLA: {} from regression landing to alert opening",
            cbench::util::fmt_secs(sla)
        );
    }
    if !cfg.drains.is_empty() {
        println!(
            "backfill {}: {} of {} job starts went into maintenance-window gaps",
            if cfg.backfill { "on" } else { "off" },
            out.jobs_backfilled(),
            out.total_jobs()
        );
    }
    println!(
        "detect mode: {}",
        if incremental { "incremental (state-carried windows)" } else { "requery (full tail re-query)" }
    );
    println!(
        "select mode: {} — {} of {} jobs run, {} carried forward ({:.2} cluster-hours and {} makespan saved)",
        cfg.select.name(),
        out.jobs_selected(),
        out.total_jobs(),
        out.jobs_skipped(),
        out.cluster_hours_saved(),
        cbench::util::fmt_secs(out.makespan_saved_s())
    );
    // machine-readable summary (CI records this in the per-commit bench JSON)
    println!(
        "CAMPAIGN_JSON {{\"repos\":{repos},\"pushes\":{pushes},\"pipelines\":{},\"jobs\":{},\"makespan_s\":{:.3},\"sequential_s\":{:.3},\"speedup\":{:.4},\"alerts_opened\":{},\"backfill\":{},\"backfilled_jobs\":{},\"collect\":\"{}\",\"first_upload_s\":{:.3},\"worst_alert_sla_s\":{},\"select\":\"{}\",\"selected_jobs\":{},\"skipped_jobs\":{},\"cluster_hours_saved\":{:.4},\"makespan_saved_s\":{:.3}}}",
        out.reports.len(),
        out.total_jobs(),
        out.makespan,
        out.sequential_baseline,
        speedup,
        out.alerts_opened(),
        cfg.backfill,
        out.jobs_backfilled(),
        if out.streaming { "streaming" } else { "batch" },
        out.first_upload_at(),
        out.worst_alert_sla()
            .map(|s| format!("{s:.3}"))
            .unwrap_or_else(|| "null".into()),
        cfg.select.name(),
        out.jobs_selected(),
        out.jobs_skipped(),
        out.cluster_hours_saved(),
        out.makespan_saved_s()
    );
    // standalone selection summary for the CI select-smoke job
    println!(
        "SELECT_JSON {{\"mode\":\"{}\",\"selected_jobs\":{},\"skipped_jobs\":{},\"carried_points\":{},\"cluster_hours_saved\":{:.4},\"makespan_saved_s\":{:.3}}}",
        cfg.select.name(),
        out.jobs_selected(),
        out.jobs_skipped(),
        out.reports.iter().map(|r| r.points_carried).sum::<usize>(),
        out.cluster_hours_saved(),
        out.makespan_saved_s()
    );

    if self_metrics {
        println!(
            "self-metrics: infra throughput uploaded as `cbench_self`{} — {} self alert(s) opened",
            if self_slowdown != 1.0 {
                format!(" (rates injected /{self_slowdown})")
            } else {
                String::new()
            },
            cb.self_alerts_opened()
        );
    }

    let rep = cb.db.save_report(Path::new(tsdb_path))?;
    cb.alerts.save(Path::new(alerts_path))?;
    cb.det_state.save(Path::new(state_path))?;
    println!(
        "tsdb saved to {tsdb_path} ({} points; {} shard file(s) rewritten, {} kept); \
         alerts saved to {alerts_path} ({} active); detector state -> {state_path}",
        cb.db.len(),
        rep.shards_written,
        rep.shards_kept,
        cb.alerts.active().len()
    );
    if let Some(tp) = args.get("save-trace") {
        cb.trace.save(Path::new(tp))?;
        println!(
            "trace saved to {tp} ({} spans) — `cbench trace show|export|critical-path --trace {tp}`",
            cb.trace.len()
        );
    }
    println!("\n{}", campaign_dashboard().render_text(&cb.db));
    Ok(())
}

/// `cbench trace <show|export|critical-path> [--trace FILE] [--chrome]
/// [--out FILE]` — inspect a cluster-time trace saved by
/// `cbench campaign|pipeline --save-trace`: `show` prints the span tree,
/// `export --chrome` emits Chrome trace-event JSON (open in Perfetto or
/// chrome://tracing), `critical-path` walks the span DAG backward from
/// the campaign end and attributes the entire makespan to run /
/// queue-wait / maintenance / collect / idle segments (prints
/// `CRITPATH_JSON`, the machine-readable breakdown CI archives).
fn cmd_trace(args: &Args) -> anyhow::Result<()> {
    let sub = args.positional.first().map(|s| s.as_str()).unwrap_or("show");
    let path = args.get_or("trace", "cbench_trace.json");
    let rec = cbench::obs::trace::TraceRecorder::load(Path::new(path))?;
    match sub {
        "show" => {
            println!("{}", rec.tree_text());
        }
        "export" => {
            let j = if args.flag("chrome") { rec.chrome_json() } else { rec.to_json() };
            let text = j.to_string_pretty();
            match args.get("out") {
                Some(out) => {
                    std::fs::write(out, &text)?;
                    println!("trace exported to {out} ({} spans)", rec.len());
                }
                None => println!("{text}"),
            }
        }
        "critical-path" | "crit" => {
            let cp = cbench::obs::trace::critical_path(rec.spans())?;
            println!("{}", cp.render_text());
            println!("CRITPATH_JSON {}", cp.to_json().to_string_compact());
        }
        other => anyhow::bail!("unknown trace subcommand `{other}` (show|export|critical-path)"),
    }
    Ok(())
}

/// `cbench cluster [--node HOST]` — show the Testcluster catalogue.
fn cmd_cluster(args: &Args) -> anyhow::Result<()> {
    match args.get("node") {
        Some(host) => {
            let n = node(host).ok_or_else(|| anyhow::anyhow!("unknown node `{host}`"))?;
            let ms = cbench::cluster::machinestate::machine_state(&n, "inspect", 0.0);
            println!("{}", ms.to_string_pretty());
        }
        None => println!("{}", report::tables::tab2_testcluster()),
    }
    Ok(())
}

/// `cbench microbench [--n SIZE] [--reps R]` — really run the
/// likwid-bench-class kernels on this host.
fn cmd_microbench(args: &Args) -> anyhow::Result<()> {
    let n = args.get_usize("n", 1 << 22);
    let reps = args.get_usize("reps", 5);
    println!("host microbenchmarks (n={n}, reps={reps}):");
    for kind in MicrobenchKind::all() {
        let r = run_host_microbench(kind, n, reps);
        println!("  {:<10} {:>10.2} {}", kind.name(), r.value, r.unit);
    }
    println!("\nper-node projections (likwid-bench stand-in):");
    for nm in catalogue() {
        let s = cbench::cluster::microbench::project_node_microbench(&nm, MicrobenchKind::Stream);
        let p = cbench::cluster::microbench::project_node_microbench(&nm, MicrobenchKind::PeakFlops);
        println!("  {:<12} stream {:>7.0} GB/s   peak {:>7.0} GFLOP/s", nm.host, s.value, p.value);
    }
    Ok(())
}

/// `cbench dashboard <fe2ti|walberla> --tsdb FILE [--select tag=v,v]`.
fn cmd_dashboard(args: &Args) -> anyhow::Result<()> {
    let which = args.positional.first().map(|s| s.as_str()).unwrap_or("walberla");
    let tsdb = args
        .get("tsdb")
        .ok_or_else(|| anyhow::anyhow!("--tsdb FILE required (see `cbench pipeline --save-tsdb`)"))?;
    let db = cbench::tsdb::Db::load(std::path::Path::new(tsdb))?;
    let mut dash = match which {
        "fe2ti" => fe2ti_dashboard(),
        // the infrastructure watching itself (`--self-metrics on` runs)
        "self" => self_observability_dashboard(),
        _ => walberla_dashboard(),
    };
    if let Some(sel) = args.get("select") {
        if let Some((tag, vals)) = sel.split_once('=') {
            let v: Vec<&str> = vals.split(',').collect();
            dash.select(tag, &v);
        }
    }
    // annotate panels with any saved, still-active regression alerts
    let book = AlertBook::load(Path::new(args.get_or("alerts", "cbench_alerts.json")))?;
    println!("{}", dash.render_text_with_alerts(&db, &book.active()));
    if let Some(field) = args.get("agg") {
        let m = if which == "fe2ti" { "fe2ti" } else { "lbm" };
        for (label, v) in Query::new(m, field)
            .group_by(&["node"])
            .run_agg(&db, Aggregate::Last)
        {
            println!("{label}: {v:.4}");
        }
    }
    Ok(())
}

/// `cbench artifacts [--dir DIR]` — list + smoke the PJRT artifacts.
fn cmd_artifacts(args: &Args) -> anyhow::Result<()> {
    let dir = args.get_or("dir", "artifacts");
    let mut engine = cbench::runtime::Engine::open(dir)?;
    println!("PJRT platform: {}", engine.platform());
    let names: Vec<String> = engine.artifact_names().iter().map(|s| s.to_string()).collect();
    for name in &names {
        let meta = engine.meta(name).unwrap();
        println!(
            "  {:<24} kind={:<16} shape={:?}{}",
            name,
            meta.kind,
            meta.shape,
            meta.vmem_bytes_per_block
                .map(|v| format!(" vmem/block={}", cbench::util::fmt_bytes(v)))
                .unwrap_or_default()
        );
    }
    if args.flag("smoke") {
        let n = 8usize;
        let cells = 19 * n * n * n;
        let f = vec![1.0f32 / 19.0; cells];
        let t = std::time::Instant::now();
        let out = engine.lbm_step("lbm_d3q19_srt_8", &f)?;
        println!(
            "\nsmoke: lbm_d3q19_srt_8 executed in {} ({} values, mass drift {:.2e})",
            cbench::util::fmt_secs(t.elapsed().as_secs_f64()),
            out.len(),
            (out.iter().sum::<f32>() - f.iter().sum::<f32>()).abs()
        );
    }
    Ok(())
}

/// Representative storage-layer query cost over a TSDB, in seconds: per
/// measurement, one detector-style trailing-window scan (tail bound +
/// range read) and one full-history scan, averaged over `reps` rounds.
/// Used by `cbench tsdb compact` to report the query-time ratio.
fn tsdb_probe_secs(db: &Db, reps: usize) -> f64 {
    let measurements: Vec<String> = db.measurements().cloned().collect();
    let t = std::time::Instant::now();
    let mut sink = 0usize;
    for _ in 0..reps.max(1) {
        for m in &measurements {
            let t0 = db.tail_start_ts(m, 16);
            sink += db.points_in_range(m, t0, None).count();
            sink += db.points_iter(m).count();
        }
    }
    // keep the scans from being optimized away
    if sink == usize::MAX {
        eprintln!("unreachable probe sink");
    }
    t.elapsed().as_secs_f64() / reps.max(1) as f64
}

/// `cbench tsdb <info|compact|export> [--tsdb STORE]` — inspect /
/// compact / dump the sharded store (manifest directory or legacy
/// single file). `info` prints the shard layout from the manifest index
/// alone — nothing is materialized; `--json` emits it machine-readable.
/// `compact --retain-raw SECS` replaces raw points in shards entirely
/// older than `newest - retain-raw` with per-series rollup summaries and
/// saves the result (`--out STORE` to write elsewhere; saving a loaded
/// legacy file migrates it to the manifest layout). `export --out FILE`
/// writes the legacy single-file line-protocol dump (stable order — the
/// CI reload-equivalence check diffs it). `--shard-span SECS`
/// re-partitions on load (a full-copy operation); without the flag a
/// manifest store keeps its recorded span.
fn cmd_tsdb(args: &Args) -> anyhow::Result<()> {
    let sub = args.positional.first().map(|s| s.as_str()).unwrap_or("info");
    let tsdb = args.get_or("tsdb", "cbench_tsdb.lp");
    let mut db = match args.get("shard-span") {
        Some(_) => {
            let span_s = args.get_usize("shard-span", 0);
            anyhow::ensure!(span_s >= 1, "--shard-span must be at least 1 second");
            Db::load_with_shard_span(Path::new(tsdb), span_s as i64 * 1_000_000_000)?
        }
        None => Db::load(Path::new(tsdb))?,
    };
    let span_s = (db.shard_span() / 1_000_000_000).max(1) as usize;
    let layout = if Path::new(tsdb).is_dir() { "manifest" } else { "legacy" };
    match sub {
        "info" => {
            let measurements: Vec<String> = db.measurements().cloned().collect();
            if args.flag("json") {
                // per-shard manifest stats, machine-readable, via the
                // real JSON writer (measurement names and paths may
                // contain characters Rust's {:?} would escape invalidly);
                // `loaded` proves the info pass itself stayed lazy.
                // min/max_ts print as JSON numbers here (display only —
                // the manifest itself stores them as exact strings).
                use cbench::util::json::Json;
                let mut meas = Json::obj();
                for m in &measurements {
                    let shards: Vec<Json> = db
                        .shards(m)
                        .iter()
                        .map(|s| {
                            Json::obj()
                                .set("key", s.key())
                                .set("points", s.len())
                                .set("min_ts", s.min_ts().unwrap_or(0))
                                .set("max_ts", s.max_ts().unwrap_or(0))
                                .set("compacted", s.is_compacted())
                                .set("loaded", s.is_loaded())
                        })
                        .collect();
                    meas = meas.set(
                        m,
                        Json::obj()
                            .set("shards", db.shards(m).len())
                            .set("points", db.n_points(m))
                            .set("shard_list", Json::Arr(shards)),
                    );
                }
                // flag unreadable shard bodies (valid manifest over a
                // truncated/corrupt/missing file) without retaining any
                // body — `loaded` above stays an honest laziness probe
                let bad = db.verify_bodies();
                let bad_json: Vec<Json> = bad
                    .iter()
                    .map(|(m, key, file, err)| {
                        Json::obj()
                            .set("measurement", m.as_str())
                            .set("key", *key)
                            .set("file", file.as_str())
                            .set("error", err.as_str())
                    })
                    .collect();
                let j = Json::obj()
                    .set("store", tsdb)
                    .set("layout", layout)
                    .set("shard_span_s", span_s)
                    .set("points", db.len())
                    .set("unreadable_shards", Json::Arr(bad_json))
                    .set("measurements", meas);
                println!("{}", j.to_string_compact());
                anyhow::ensure!(bad.is_empty(), "{} unreadable shard bodies", bad.len());
                return Ok(());
            }
            println!("{tsdb}: {} points, shard span {span_s} s, {layout} layout", db.len());
            for m in &measurements {
                println!("  {m}: {} shards, {} points", db.shards(m).len(), db.n_points(m));
                for s in db.shards(m) {
                    println!(
                        "    shard {:>6}  [{}..{}]  {:>6} points{}{}",
                        s.key(),
                        s.min_ts().unwrap_or(0) / 1_000_000_000,
                        s.max_ts().unwrap_or(0) / 1_000_000_000,
                        s.len(),
                        if s.is_compacted() { "  (compacted rollups)" } else { "" },
                        if s.is_loaded() { "" } else { "  (lazy)" }
                    );
                }
            }
            let bad = db.verify_bodies();
            for (m, key, file, err) in &bad {
                eprintln!("UNREADABLE shard {m}/{key} ({file}): {err}");
            }
            anyhow::ensure!(
                bad.is_empty(),
                "{} unreadable shard bodies — the store was modified behind the manifest",
                bad.len()
            );
            Ok(())
        }
        "compact" => {
            let retain_s = args.get_usize("retain-raw", 64);
            let t_before = tsdb_probe_secs(&db, 3);
            let rep = db.compact(retain_s as i64 * 1_000_000_000);
            let t_after = tsdb_probe_secs(&db, 3);
            let out = args.get_or("out", tsdb);
            let persist = db.save_report(Path::new(out))?;
            let ratio = if t_before > 0.0 { t_after / t_before } else { 1.0 };
            println!(
                "compacted {} of {} shards: {} -> {} points (raw kept for the trailing {retain_s} s) -> {out} ({} shard file(s) rewritten, {} kept)",
                rep.shards_compacted,
                rep.shards_seen,
                rep.points_before,
                rep.points_after,
                persist.shards_written,
                persist.shards_kept
            );
            println!(
                "storage-scan probe: {:.3} ms -> {:.3} ms ({ratio:.2}x)",
                1e3 * t_before,
                1e3 * t_after
            );
            // machine-readable summary (CI embeds this in the per-commit
            // bench JSON next to CAMPAIGN_JSON / BACKFILL_JSON)
            println!(
                "COMPACT_JSON {{\"points_before\":{},\"points_after\":{},\"shards_seen\":{},\"shards_compacted\":{},\"retain_raw_s\":{retain_s},\"shard_span_s\":{span_s},\"query_time_ratio\":{ratio:.4}}}",
                rep.points_before, rep.points_after, rep.shards_seen, rep.shards_compacted
            );
            Ok(())
        }
        "export" => {
            let out = args
                .get("out")
                .ok_or_else(|| anyhow::anyhow!("tsdb export needs --out FILE"))?;
            db.export_lp(Path::new(out))?;
            println!("exported {} points -> {out} (legacy single-file line protocol)", db.len());
            Ok(())
        }
        other => anyhow::bail!("unknown subcommand `tsdb {other}` (info|compact|export)"),
    }
}

/// Process-wide shutdown flag for `cbench serve` — flipped by the
/// SIGTERM/SIGINT handler, polled by the serve foreground loop.
#[cfg(unix)]
static SERVE_SHUTDOWN: std::sync::atomic::AtomicBool = std::sync::atomic::AtomicBool::new(false);

#[cfg(unix)]
extern "C" fn serve_signal_handler(_sig: libc::c_int) {
    SERVE_SHUTDOWN.store(true, std::sync::atomic::Ordering::SeqCst);
}

/// `cbench serve [--addr A] [--data-dir DIR] [--serve-threads N]
/// [--max-body BYTES] [--read-timeout-ms MS]` — run the
/// benchmark-as-a-service facade in the foreground until SIGTERM/SIGINT,
/// then drain in-flight requests, save every project store (crash-atomic
/// manifest protocol) and print `SERVE_SHUTDOWN_JSON`; CI asserts
/// `dirty_after_save == 0`.
fn cmd_serve(args: &Args) -> anyhow::Result<()> {
    use cbench::serve::{start, ServeConfig};
    let def = ServeConfig::default();
    let cfg = ServeConfig {
        addr: args.get_or("addr", &def.addr).to_string(),
        data_dir: args.get("data-dir").map(PathBuf::from),
        threads: args.get_usize("serve-threads", def.threads).max(1),
        max_body: args.get_usize("max-body", def.max_body),
        read_timeout_ms: args.get_usize("read-timeout-ms", def.read_timeout_ms as usize) as u64,
    };
    let handle = start(cfg).map_err(|e| anyhow::anyhow!(e))?;
    println!(
        "cbench serve: listening on http://{} ({} workers{})",
        handle.addr,
        handle.threads(),
        match handle.data_dir() {
            Some(d) => format!(", data-dir {}", d.display()),
            None => ", in-memory only".to_string(),
        }
    );
    #[cfg(unix)]
    {
        unsafe {
            libc::signal(libc::SIGTERM, serve_signal_handler as libc::sighandler_t);
            libc::signal(libc::SIGINT, serve_signal_handler as libc::sighandler_t);
        }
        while !SERVE_SHUTDOWN.load(std::sync::atomic::Ordering::SeqCst) {
            std::thread::sleep(std::time::Duration::from_millis(100));
        }
        println!("cbench serve: shutdown signal — draining and saving");
    }
    #[cfg(not(unix))]
    {
        // no signal story off unix: serve until the process is killed
        loop {
            std::thread::sleep(std::time::Duration::from_secs(3600));
        }
    }
    let report = handle.stop();
    println!(
        "SERVE_SHUTDOWN_JSON {}",
        report.to_json().to_string_compact()
    );
    anyhow::ensure!(
        report.dirty_after_save == 0,
        "{} shards still dirty after the shutdown save",
        report.dirty_after_save
    );
    Ok(())
}

/// `cbench loadgen [--addr A] [--project P] [--clients N] [--batches B]
/// [--batch-points K] [--queries Q] [--inject]` — drive a running
/// serve:: instance with concurrent ingest + query traffic and print
/// `LOADGEN_JSON` (QPS, p50/p99 latency, open alerts read back over the
/// API). `--inject` appends single-point regressed batches so the stock
/// detector opens an alert the smoke job can assert on.
fn cmd_loadgen(args: &Args) -> anyhow::Result<()> {
    use cbench::serve::loadgen::{run, LoadgenConfig};
    let def = LoadgenConfig::default();
    let cfg = LoadgenConfig {
        addr: args.get_or("addr", &def.addr).to_string(),
        project: args.get_or("project", &def.project).to_string(),
        clients: args.get_usize("clients", def.clients).max(1),
        batches: args.get_usize("batches", def.batches).max(1),
        batch_points: args.get_usize("batch-points", def.batch_points).max(1),
        queries: args.get_usize("queries", def.queries),
        inject_regression: args.flag("inject"),
    };
    let report = run(&cfg);
    println!("LOADGEN_JSON {}", report.to_json().to_string_compact());
    anyhow::ensure!(
        report.http_errors == 0,
        "{} of {} requests failed",
        report.http_errors,
        report.ingest_requests + report.query_requests
    );
    Ok(())
}

/// Latest timestamp across every measurement — the "now" for alert
/// bookkeeping when working from a saved TSDB. Reads shard metadata
/// only: a lazily-loaded manifest store stays unmaterialized.
fn db_now(db: &Db) -> i64 {
    db.newest_ts().unwrap_or(0)
}

/// `cbench regress <detect|alerts|bisect>` — the detect → alert → bisect
/// loop over the state a `cbench pipeline` run saved.
fn cmd_regress(args: &Args) -> anyhow::Result<()> {
    let sub = args.positional.first().map(|s| s.as_str()).unwrap_or("alerts");
    let alerts_path = args.get_or("alerts", "cbench_alerts.json");
    match sub {
        "detect" => cmd_regress_detect(args, alerts_path),
        "alerts" => cmd_regress_alerts(args, alerts_path),
        "bisect" if args.flag("campaign") => cmd_regress_bisect_campaign(args, alerts_path),
        "bisect" => cmd_regress_bisect(args, alerts_path),
        other => anyhow::bail!("unknown subcommand `regress {other}` (detect|alerts|bisect)"),
    }
}

/// `cbench regress detect [--tsdb FILE] [--alerts FILE]` — run the
/// statistical detector over a saved TSDB and fold findings into the
/// alert book.
///
/// Detection iterates (measurement × repo tag value) and runs each check
/// *scoped* to that repository, matching the pipeline-path semantics:
/// the `tail(n)` detection window counts each repo's own trigger
/// timestamps, so co-tenant uploads cannot dilute (or shrink) another
/// repo's window. (The unscoped `detect_full` used here before judged
/// every series against the measurement-wide tail bound — the documented
/// PR-2 caveat this fixes.) Measurements without a `repo` tag keep the
/// unscoped check. Policies that don't group by `repo` evaluate the same
/// series identically under every scope; the fingerprint dedup below
/// collapses those repeats before the alert book sees them.
fn cmd_regress_detect(args: &Args, alerts_path: &str) -> anyhow::Result<()> {
    use cbench::regress::detector::series_fingerprint;
    let tsdb = args.get_or("tsdb", "cbench_tsdb.lp");
    let db = Db::load(Path::new(tsdb))?;
    let det = Detector::with_default_policies();
    let mut findings = Vec::new();
    let mut evaluated = Vec::new();
    let measurements: Vec<String> = db.measurements().cloned().collect();
    for m in &measurements {
        let repos = db.tag_values(m, "repo");
        if repos.is_empty() {
            let (f, e) = det.detect_measurement(&db, m);
            findings.extend(f);
            evaluated.extend(e);
        } else {
            for r in &repos {
                let (f, e) = det.detect_measurement_scoped(&db, m, &[("repo", r)]);
                findings.extend(f);
                evaluated.extend(e);
            }
        }
    }
    let mut seen = std::collections::BTreeSet::new();
    findings.retain(|f| seen.insert(series_fingerprint(&f.policy, &f.series)));
    let mut seen_eval = std::collections::BTreeSet::new();
    evaluated.retain(|e| seen_eval.insert(e.clone()));
    if findings.is_empty() {
        println!("no regressions detected across {} points", db.len());
    } else {
        let mut t = Table::new(&[
            "series", "baseline", "current", "change", "p-value", "confidence", "suspect commit",
        ]);
        for f in &findings {
            t.row(&[
                format!("{}.{} {}", f.measurement, f.field, f.series),
                format!("{:.3} ±{:.3}", f.baseline.mean, f.baseline.sd),
                format!("{:.3}", f.current),
                format!("{:+.1}%", 100.0 * f.rel_change),
                f.best_p().map(|p| format!("{p:.2e}")).unwrap_or_else(|| "-".into()),
                format!("{:.2}", f.confidence),
                f.suspect_commit.clone().unwrap_or_else(|| "?".into()),
            ]);
        }
        println!("{}", t.render());
    }
    let mut book = AlertBook::load(Path::new(alerts_path))?;
    let s = book.ingest(&findings, &evaluated, db_now(&db));
    book.save(Path::new(alerts_path))?;
    println!(
        "alerts: {} opened, {} re-confirmed, {} auto-resolved ({} active) -> {alerts_path}",
        s.opened,
        s.updated,
        s.auto_resolved,
        book.active().len()
    );
    Ok(())
}

/// `cbench regress alerts [--ack ID] [--resolve ID] [--all]` — list and
/// manage the alert lifecycle.
fn cmd_regress_alerts(args: &Args, alerts_path: &str) -> anyhow::Result<()> {
    let mut book = AlertBook::load(Path::new(alerts_path))?;
    let mut dirty = false;
    if let Some(id) = args.get("ack").and_then(|v| v.parse::<u64>().ok()) {
        book.acknowledge(id).map_err(|e| anyhow::anyhow!(e))?;
        println!("alert #{id} acknowledged");
        dirty = true;
    }
    if let Some(id) = args.get("resolve").and_then(|v| v.parse::<u64>().ok()) {
        let now = book.alerts.iter().map(|a| a.last_seen_ts).max().unwrap_or(0);
        book.resolve(id, now).map_err(|e| anyhow::anyhow!(e))?;
        println!("alert #{id} resolved");
        dirty = true;
    }
    if dirty {
        book.save(Path::new(alerts_path))?;
    }
    let show_all = args.flag("all");
    let mut t = Table::new(&[
        "id", "state", "series", "change", "confidence", "seen", "sla", "queue+run+collect+detect",
        "suspect", "first-bad",
    ]);
    let mut shown = 0;
    for a in &book.alerts {
        if !show_all && a.state == AlertState::Resolved {
            continue;
        }
        t.row(&[
            format!("{}", a.id),
            a.state.name().to_string(),
            format!("{}.{} {}", a.measurement, a.field, a.series),
            format!("{:+.1}%", 100.0 * a.rel_change),
            format!("{:.2}", a.confidence),
            format!("{}x", a.times_seen),
            a.sla_secs
                .map(cbench::util::fmt_secs)
                .unwrap_or_else(|| "-".into()),
            // where the SLA went (components sum to `sla` exactly)
            match (
                a.sla_queue_secs,
                a.sla_run_secs,
                a.sla_collect_secs,
                a.sla_detect_secs,
            ) {
                (Some(q), Some(r), Some(c), Some(d)) => {
                    format!("{q:.0}+{r:.0}+{c:.0}+{d:.0}")
                }
                _ => "-".into(),
            },
            a.suspect_commit.clone().unwrap_or_else(|| "?".into()),
            a.first_bad_commit.clone().unwrap_or_else(|| "-".into()),
        ]);
        shown += 1;
    }
    if shown == 0 {
        println!(
            "no {} alerts in {alerts_path}",
            if show_all { "recorded" } else { "active" }
        );
    } else {
        println!("{}", t.render());
    }
    Ok(())
}

/// `cbench regress bisect [--pipeline walberla] [--commits N]
/// [--inject-regression K] [--penalty P] [--alert ID]` — rebuild the
/// deterministic commit chain the pipeline benchmarked (same arguments!)
/// and binary-search the first bad commit for the highest-confidence
/// active alert (or `--alert ID`).
fn cmd_regress_bisect(args: &Args, alerts_path: &str) -> anyhow::Result<()> {
    let which = args.get_or("pipeline", "walberla").to_string();
    anyhow::ensure!(
        which == "fe2ti" || which == "walberla",
        "unknown pipeline `{which}` (fe2ti|walberla)"
    );
    let commits = args.get_usize("commits", 8);
    let inject_at = args.get_usize("inject-regression", 0);
    let penalty = args.get_f64("penalty", 0.15);
    let measurement = if which == "fe2ti" { "fe2ti" } else { "lbm" };

    let mut book = AlertBook::load(Path::new(alerts_path))?;
    let candidates: Vec<u64> = book
        .active()
        .iter()
        .filter(|a| a.measurement == measurement)
        .map(|a| a.id)
        .collect();
    anyhow::ensure!(
        !candidates.is_empty(),
        "no active `{measurement}` alerts in {alerts_path} — run `cbench regress detect` first"
    );
    let alert_id = pick_alert(&book, &candidates, args, measurement)?;
    let alert = book.get(alert_id).unwrap().clone();
    // this path rebuilds the single-repo `cbench pipeline` chain, whose
    // repo tag is the pipeline name itself — an alert carrying any other
    // repository came from campaign state and would probe the wrong chain
    if let Some(r) = alert.group.get("repo") {
        anyhow::ensure!(
            r == "<none>" || r == &which,
            "alert #{} belongs to repository `{r}` — that is campaign state. \
             Re-run as `cbench regress bisect --campaign --repos N --pushes M \
             [--seed S] [--inject-regression K]` with the original campaign \
             arguments (they rebuild the exact commit chains), or pick a \
             single-repo alert with --alert ID",
            alert.id
        );
    }
    println!(
        "bisecting alert #{}: {}.{} {} ({:+.1}%)",
        alert.id,
        alert.measurement,
        alert.field,
        alert.series,
        100.0 * alert.rel_change
    );

    let (repo, events) = simulated_history(&which, commits, inject_at, penalty);
    anyhow::ensure!(
        events.len() >= 2,
        "need at least 2 commits to bisect (--commits {commits})"
    );
    let good = events.first().unwrap().commit_id.clone();
    let bad = events.last().unwrap().commit_id.clone();
    let mut cb = CbSystem::new();
    let report = bisect_pipeline(
        &mut cb,
        &repo,
        "master",
        &good,
        &bad,
        measurement,
        &alert.field,
        &alert.group,
        alert.direction,
        policy_threshold(&alert.policy),
        |repo, commit| pipeline_jobs_for(&which, repo, commit),
    )?;
    finish_bisection(&mut book, alert_id, &repo, &events, &report, alerts_path)
}

/// Resolve `--alert ID` against a candidate set (validating it), or
/// default to the highest-confidence candidate. `what` names the
/// candidate class for the error message. Shared by the single-repo and
/// campaign bisect paths.
fn pick_alert(book: &AlertBook, candidates: &[u64], args: &Args, what: &str) -> anyhow::Result<u64> {
    match args.get("alert").and_then(|v| v.parse::<u64>().ok()) {
        Some(id) => {
            anyhow::ensure!(
                candidates.contains(&id),
                "alert #{id} is not an active {what} alert"
            );
            Ok(id)
        }
        None => {
            // highest confidence first
            let mut best = candidates[0];
            for &id in candidates {
                if book.get(id).unwrap().confidence > book.get(best).unwrap().confidence {
                    best = id;
                }
            }
            Ok(best)
        }
    }
}

/// `min_rel_change` of a stock policy — probes are classified with the
/// same sensitivity the alert's policy used.
fn policy_threshold(policy: &str) -> f64 {
    Detector::with_default_policies()
        .policies
        .iter()
        .find(|p| p.name == policy)
        .map(|p| p.min_rel_change)
        .unwrap_or(0.08)
}

/// Print a bisection's probe log + verdict and persist the first-bad
/// commit onto the alert (shared by the single-repo and campaign paths).
fn finish_bisection(
    book: &mut AlertBook,
    alert_id: u64,
    repo: &Repository,
    events: &[PushEvent],
    report: &BisectReport,
    alerts_path: &str,
) -> anyhow::Result<()> {
    for (cid, v, is_bad) in &report.tested {
        let idx = events.iter().position(|e| &e.commit_id == cid);
        println!(
            "  probe commit {} (#{}) -> {:.3} [{}]",
            &cid[..8],
            idx.map(|i| (i + 1).to_string()).unwrap_or_else(|| "?".into()),
            v,
            if *is_bad { "BAD" } else { "good" }
        );
    }
    match &report.first_bad {
        Some(cid) => {
            let idx = events.iter().position(|e| &e.commit_id == cid);
            let msg = repo.get(cid).map(|c| c.message.clone()).unwrap_or_default();
            println!(
                "first bad commit: {} (#{}) \"{}\"",
                &cid[..8],
                idx.map(|i| (i + 1).to_string()).unwrap_or_else(|| "?".into()),
                msg
            );
            println!(
                "pipeline re-runs: {} (linear scan would need {})",
                report.pipeline_runs, report.linear_runs
            );
            if let Some(a) = book.get_mut(alert_id) {
                a.first_bad_commit = Some(cid[..8.min(cid.len())].to_string());
                if a.state == AlertState::Open {
                    a.state = AlertState::Acknowledged;
                }
            }
            book.save(Path::new(alerts_path))?;
            println!("alert #{alert_id} updated with first-bad commit -> {alerts_path}");
        }
        None => println!("bisection inconclusive"),
    }
    Ok(())
}

/// `cbench regress bisect --campaign [--repos N] [--pushes M] [--seed S]
/// [--inject-regression K] [--penalty P] [--alert ID]` — campaign-aware
/// bisection (the ROADMAP item): rebuild the deterministic commit chains
/// a `cbench campaign` run benchmarked (same arguments reproduce the
/// same chains, `campaign_push_events`), pick the campaign project the
/// alert's `repo` tag names, and binary-search that project's chain with
/// its real job matrix. Probes ride the shared event-driven scheduler
/// like any live pipeline.
fn cmd_regress_bisect_campaign(args: &Args, alerts_path: &str) -> anyhow::Result<()> {
    let repos = args.get_usize("repos", 2);
    let pushes = args.get_usize("pushes", 2);
    let inject_at = args.get_usize("inject-regression", 0);
    let penalty = args.get_f64("penalty", 0.15);
    let seed = args.get_usize("seed", 42) as u64;
    anyhow::ensure!(repos >= 1, "--repos must be at least 1");
    anyhow::ensure!(
        pushes >= 2,
        "need at least 2 push rounds to bisect (--pushes {pushes})"
    );

    let mut projects = campaign::default_projects(repos);
    let cfg = CampaignConfig { pushes, inject_at, penalty, seed, ..CampaignConfig::default() };
    let events = campaign::campaign_push_events(&mut projects, &cfg);

    let mut book = AlertBook::load(Path::new(alerts_path))?;
    let candidates: Vec<u64> = book
        .active()
        .iter()
        .filter(|a| {
            a.group
                .get("repo")
                .map(|r| projects.iter().any(|p| &p.name == r))
                .unwrap_or(false)
        })
        .map(|a| a.id)
        .collect();
    anyhow::ensure!(
        !candidates.is_empty(),
        "no active alert names a campaign repository (--repos {repos}) in {alerts_path} — \
         run `cbench campaign --inject-regression K` first, or bisect \
         single-repo state without --campaign"
    );
    let alert_id = pick_alert(&book, &candidates, args, "campaign-repository")?;
    let alert = book.get(alert_id).unwrap().clone();
    let repo_name = alert.group.get("repo").cloned().expect("candidate has repo");
    let pi = projects
        .iter()
        .position(|p| p.name == repo_name)
        .expect("candidate repo is a project");
    let chain: Vec<PushEvent> = events
        .iter()
        .filter(|(i, _)| *i == pi)
        .map(|(_, e)| e.clone())
        .collect();
    anyhow::ensure!(chain.len() >= 2, "project `{repo_name}` has fewer than 2 pushes");
    println!(
        "bisecting campaign alert #{}: {}.{} {} ({:+.1}%) over repository `{repo_name}` ({} pushes)",
        alert.id,
        alert.measurement,
        alert.field,
        alert.series,
        100.0 * alert.rel_change,
        chain.len()
    );
    let good = chain.first().unwrap().commit_id.clone();
    let bad = chain.last().unwrap().commit_id.clone();
    let kind = projects[pi].kind;
    let mut cb = CbSystem::new();
    let report = bisect_pipeline(
        &mut cb,
        &projects[pi].repo,
        "master",
        &good,
        &bad,
        &alert.measurement,
        &alert.field,
        &alert.group,
        alert.direction,
        policy_threshold(&alert.policy),
        |repo, commit| kind.jobs_for(repo, commit),
    )?;
    finish_bisection(&mut book, alert_id, &projects[pi].repo, &chain, &report, alerts_path)
}

const HELP: &str = "\
cbench — continuous benchmarking infrastructure for HPC applications
(reproduction of Alt et al. 2024, DOI 10.1080/17445760.2024.2360190)

USAGE: cbench <command> [options]

COMMANDS:
  report <id>|all [--out DIR]   regenerate a paper table/figure
                                (tab1..3, fig5..fig14; side CSV/SVG with --out)
  pipeline <fe2ti|walberla>     run the CB pipeline on simulated commits
           [--commits N] [--inject-regression K] [--penalty P]
           [--save-tsdb STORE] [--save-alerts FILE] [--save-state FILE]
           [--detect incremental|requery] [--save-trace FILE]
           [--shard-cache N] [--threads N]
                                K plants the waLBerla kernel regression at
                                commit #K (penalty P, default 0.15); state
                                persists to cbench_tsdb.lp (a manifest
                                directory: shard index + one line-protocol
                                file per shard; saves rewrite only dirty
                                shards) / cbench_alerts.json /
                                cbench_detector_state.json (the carried
                                per-series detection windows)
  pipeline describe             explain the pipeline wiring (Figs. 3-4)
  campaign [--repos N] [--pushes M] [--inject-regression K] [--penalty P]
           [--seed S] [--backfill on|off] [--drain NODE@FROM..TO[,..]]
           [--collect streaming|batch] [--detect incremental|requery]
           [--select change-aware|full]
           [--save-tsdb STORE] [--save-alerts FILE] [--save-state FILE]
           [--save-trace FILE] [--self-metrics on|off] [--self-slowdown F]
           [--shard-cache N] [--threads N]
                                multi-repo coordinator: N repositories
                                (alternating walberla/fe2ti) x M pushes,
                                every pipeline overlapped on ONE
                                event-driven scheduler (sched::) with
                                fair-share between repos; reports the
                                simulated makespan vs the sequential
                                back-to-back baseline. --collect
                                streaming (default) uploads + runs
                                detection on each pipeline's results at
                                its completion instant on the simulated
                                clock, while the roster still runs —
                                first upload and alert SLA are bounded by
                                one pipeline, not the makespan; --collect
                                batch drains the cluster first (A/B
                                reference, same TSDB benchmark contents /
                                alerts / timeline, later uploads).
                                --drain opens scontrol-style maintenance
                                windows (no job may start inside; a job
                                whose timelimit crosses one waits for
                                resume); --backfill off disables the
                                conservative timelimit-aware gap filling
                                for A/B runs (TO must be finite:
                                campaigns never resume a node themselves);
                                --detect requery restores the full
                                tail re-query per collect (A/B reference;
                                incremental is the default and produces
                                the identical alert book, byte for byte);
                                --select change-aware runs only the jobs
                                whose CB_COMPONENTS declaration a push's
                                changed paths can affect and carries the
                                rest forward (points tagged carried=1:
                                non-evidence to the detector — they keep
                                series fresh and alerts' bookkeeping
                                identical to --select full, but never
                                open or auto-resolve alerts; reports
                                SELECT_JSON with the saved cluster-hours
                                and makespan; default: full);
                                --save-trace records the cluster-time
                                span tree (see `trace`); --self-metrics
                                on uploads the coordinator's own
                                throughput as `cbench_self` so the stock
                                detector watches the infrastructure
                                (--self-slowdown F divides the uploaded
                                rates: a CI fault injector);
                                --shard-cache N caps loaded shard bodies
                                (LRU eviction, lazy re-materialization);
                                --threads N sets the worker count for the
                                parallel collect/detect, shard I/O and
                                batched line-protocol parse fan-outs
                                (global, any command; default: one worker
                                per core; results are byte-identical for
                                any N -- only wall-clock changes); with
                                N > 1 streaming campaigns also overlap
                                collect parsing with scheduling on
                                background threads (commits stay serial
                                in completion order, so artifacts are
                                still byte-identical; gated off under
                                --self-metrics on)
  trace <show|export|critical-path> [--trace FILE] [--chrome] [--out FILE]
                                inspect a saved cluster-time trace:
                                show prints the span tree; export
                                --chrome emits Chrome trace-event JSON
                                (Perfetto / chrome://tracing);
                                critical-path attributes the WHOLE
                                makespan to run / queue-wait /
                                maintenance / collect / idle segments,
                                exactly and deterministically, plus
                                per-node and per-repo breakdowns
                                (prints CRITPATH_JSON)
  tsdb info [--tsdb STORE] [--shard-span SECS] [--json]
                                shard layout of a saved TSDB from the
                                manifest index alone (nothing is parsed):
                                per-shard point counts, min/max-ts index,
                                compaction + lazy-load state; --json for
                                machine-readable per-shard manifest stats
  tsdb compact [--tsdb STORE] [--retain-raw SECS] [--shard-span SECS]
               [--out STORE]
                                retention pass for multi-year histories:
                                shards entirely older than newest -
                                retain-raw get their raw points replaced
                                by per-series rollup summaries (per-field
                                mean, rollup=mean tag, raw count in
                                rollup_n); queries over the retained raw
                                range are unchanged; prints COMPACT_JSON.
                                Saving a legacy single-file store writes
                                the manifest directory layout (in-place
                                migration); only mutated shards are
                                rewritten on an existing manifest store
  tsdb export --out FILE [--tsdb STORE]
                                dump a store (manifest or legacy) as one
                                legacy line-protocol file, stable order —
                                the reload-equivalence dump CI diffs, and
                                the down-migration path
  serve [--addr A] [--data-dir DIR] [--serve-threads N] [--max-body BYTES]
        [--read-timeout-ms MS]
                                benchmark-as-a-service facade: a
                                multi-tenant HTTP/1.1 API (std::net, no
                                new deps) over the CB core — POST
                                /v0/projects/{p}/ingest (line protocol
                                -> scoped detection -> alert book), GET
                                .../query (tail/range pushdowns), GET
                                .../alerts + POST
                                .../alerts/{id}/resolve, PUT
                                .../thresholds (per-project regress.*
                                overrides, detector-fingerprint
                                invalidation), GET /healthz, GET
                                /metrics; every project is an
                                independent core behind its own lock
                                (--data-dir persists each under
                                DIR/{project}/). SIGTERM/SIGINT drains
                                in-flight requests, saves every project
                                via the crash-atomic manifest protocol
                                and prints SERVE_SHUTDOWN_JSON
                                (dirty_after_save must be 0)
  loadgen [--addr A] [--project P] [--clients N] [--batches B]
          [--batch-points K] [--queries Q] [--inject]
                                drive a running serve instance: N client
                                threads (disjoint projects) send B
                                ingest batches of K points then Q tail
                                queries each; prints LOADGEN_JSON
                                (ingest/query QPS, p50/p99 latency ms,
                                open alerts read back over the API);
                                --inject appends single-point regressed
                                batches so the stock detector opens an
                                alert the serve-smoke CI job asserts on
  regress detect [--tsdb FILE] [--alerts FILE]
                                statistical regression scan of a saved TSDB
                                (baseline windows, Welch t / Mann-Whitney /
                                CUSUM change-point location)
  regress alerts [--ack ID] [--resolve ID] [--all]
                                list + manage the alert lifecycle
                                (open -> acknowledged -> resolved)
  regress bisect [--pipeline P] [--commits N] [--inject-regression K]
                 [--penalty P] [--alert ID]
                                binary-search the first bad commit for an
                                active alert by re-running the pipeline on
                                midpoint commits (same args as `pipeline`
                                rebuild the identical commit chain)
  regress bisect --campaign [--repos N] [--pushes M] [--seed S]
                 [--inject-regression K] [--penalty P] [--alert ID]
                                campaign-aware bisection: the same
                                arguments as `campaign` rebuild the exact
                                commit chains it benchmarked; the chain of
                                the repository named by the alert's repo
                                tag is bisected with that project's real
                                job matrix on the shared scheduler
  cluster [--node HOST]         Testcluster catalogue / machinestate dump
  microbench [--n N] [--reps R] run stream/copy/load/peakflops on this host
  dashboard <fe2ti|walberla|self> --tsdb FILE [--select tag=v1,v2] [--alerts FILE]
                                render a dashboard from a saved TSDB,
                                annotated with active regression alerts
                                (`self` shows the infra's own throughput
                                from --self-metrics runs)
  artifacts [--dir DIR] [--smoke]
                                list + smoke-test the AOT PJRT artifacts
  help                          this help

THE CB LOOP (end-to-end demo):
  cbench pipeline walberla --commits 8 --inject-regression 5
  cbench regress detect         # flags the drop, opens alerts w/ confidence
  cbench regress bisect --commits 8 --inject-regression 5
                                # pins commit #5 in O(log n) pipeline re-runs

MULTI-REPO OVERLAP (the sched:: execution model):
  cbench campaign --repos 2 --pushes 3
                                # 6 pipelines interleaved on one cluster;
                                # prints overlapped makespan vs sequential

MAINTENANCE + BACKFILL (scheduler realism):
  cbench campaign --repos 2 --pushes 2 --drain medusa@400..8000
                                # medusa drained over [400s, 8000s): only
                                # jobs whose timelimit fits the gap are
                                # backfilled in front of the window
  cbench campaign --repos 2 --pushes 2 --drain medusa@400..8000 --backfill off
                                # same roster, no gap filling -- compare
                                # the two CAMPAIGN_JSON makespans

STREAMING COLLECT + ALERT SLA (detection latency):
  cbench campaign --repos 2 --pushes 2 --inject-regression 2
                                # streaming (default): results upload at
                                # each pipeline's completion; the alert
                                # opens while other pipelines still run
  cbench campaign --repos 2 --pushes 2 --inject-regression 2 --collect batch
                                # A/B: same alerts, but first_upload_s ==
                                # makespan and the alert SLA pays the
                                # whole roster -- compare CAMPAIGN_JSON
  cbench regress bisect --campaign --repos 2 --pushes 2 --inject-regression 2
                                # campaign-aware bisection of the alert

CHANGE-AWARE SELECTION (select:: -- skip what a push cannot affect):
  cbench campaign --repos 2 --pushes 4 --select change-aware
                                # jobs whose CB_COMPONENTS declaration the
                                # push's changed paths cannot affect are
                                # skipped; their last measured points are
                                # carried forward as carried=1 (detector
                                # non-evidence) -- SELECT_JSON reports
                                # skipped_jobs + cluster_hours_saved
  cbench campaign --repos 2 --pushes 4 --select full
                                # A/B reference: identical alert book,
                                # byte for byte (CI's select-smoke diffs
                                # the two); bisect probes always re-run
                                # the full matrix regardless of --select

OBSERVABILITY (the infrastructure watching itself):
  cbench campaign --repos 2 --pushes 2 --drain medusa@400..8000 \\
                  --save-trace trace.json
  cbench trace show --trace trace.json
                                # the span tree: campaign > pipeline >
                                # job > queue/run, collect, detect
  cbench trace critical-path --trace trace.json
                                # where did the makespan go? run vs
                                # queue-wait vs maintenance vs collect,
                                # attributed 100% (+-0), per node + repo
  cbench trace export --chrome --out trace.chrome.json
                                # open in Perfetto / chrome://tracing
  cbench campaign --repos 2 --pushes 2 --self-metrics on
                                # parse/insert/sync throughput uploaded
                                # as cbench_self; the stock
                                # self-throughput policy alerts when the
                                # infra itself slows down (inject with
                                # --self-slowdown 100 on a resumed run)
  cbench dashboard self --tsdb cbench_tsdb.lp

MULTI-YEAR HISTORIES (shards + compaction + manifest persistence):
  cbench tsdb info              # shard layout of cbench_tsdb.lp, read
                                # from the manifest index alone
  cbench tsdb compact --retain-raw 64
                                # roll up shards older than the trailing
                                # 64 simulated seconds; prints pre/post
                                # point counts + query-time ratio
  cbench tsdb export --out dump.lp
                                # stable single-file dump (byte-identical
                                # across reloads -- CI asserts it)

PERSISTENCE (the manifest layout; PERSIST_JSON in bench_regress):
  cbench_tsdb.lp/ is a directory: manifest.json (shard index) + one
  line-protocol file per shard. Loads parse the manifest eagerly and
  shard bodies lazily -- resuming on a compacted multi-year history
  parses only the shards the first queries touch, so cold-load cost is
  flat in history depth. Saves rewrite only dirty (mutated) shards, via
  temp-file + rename; stray *.tmp leftovers are cleaned on load. Legacy
  single-file stores load transparently and migrate on their first save.
  Detection state (cbench_detector_state.json) carries each series'
  rolling window across runs, so per-collect detection updates from the
  new points instead of re-querying the tail window -- byte-identical
  findings/alerts either way (--detect requery is the A/B reference);
  the state invalidates and rebuilds itself on regress.* config changes.

The full architecture walkthrough (data flow, module map, determinism /
replay contract) lives in ARCHITECTURE.md at the repository root.
";

const PIPELINE_DESCRIPTION: &str = "\
CB pipeline wiring (paper Figs. 3-4):

  commit pushed to repo (vcs::)
    -> pipeline triggered (ci::, proxy-repo trigger API for walberla)
    -> job matrix generated (coordinator::fe2ti_pipeline: >80 jobs =
       nodes x compilers x solvers x parallelization;
       coordinator::walberla_pipeline: 11 nodes x 4 collision ops + FSLBM)
    -> job scripts assembled (ci::assemble_job_script, Listing 1)
    -> SUBMIT phase (coordinator::submit_pipeline): under `--select
       change-aware` the selector (select::) first classifies the push's
       changed paths to components and drops every job whose
       CB_COMPONENTS declaration the change cannot affect (undeclared
       jobs and config/build/CI changes always run; skipped jobs are
       carried forward at collect as carried=1 points -- detector
       non-evidence, so the alert book stays byte-identical to --select
       full); the surviving jobs are queued on the
       event-driven scheduler (sched:: over cluster:: node models) tagged
       with pipeline batch + repository owner + priority + timelimit
       (SLURM_TIMELIMIT from the job matrix, sbatch --time grammar);
       pipelines from other repositories interleave on the same nodes
       (fair-share picks who runs when a slot frees) -- `cbench campaign`
       keeps many in flight; the old sbatch --wait contract survives as
       slurm::, including scontrol-style drain/resume
    -> dispatch is maintenance-aware: inside a drain window no job
       starts; a job whose timelimit crosses a window waits for the
       resume edge (its shadow start), and conservative backfill slots
       shorter-limit jobs into the gap without ever delaying it
    -> COLLECT phase (coordinator::collect_pipeline): STREAMING by
       default -- the campaign driver steps the event queue one simulated
       instant at a time (sched::step_epoch) and collects each pipeline
       at the instant its last job finished, while the rest of the
       roster still runs; upload + detection below are serialized per
       pipeline in (completion time, pipeline id) order, so batch
       collection (--collect batch) produces the identical TSDB /
       alerts / timeline, just later. WITHIN one pipeline's collect the
       hot work fans out across the par:: worker pool (--threads N):
       job-log parsing, per-series detection, shard materialization and
       dirty-shard writes run in parallel and merge back in input order,
       so every artifact stays byte-identical for any thread count;
       ACROSS pipelines (still --threads N > 1) the collect's pure parse
       phase runs on background threads while the scheduler advances
       toward the next completion -- commits (detector + TSDB + alerts)
       stay serial on the driver thread in (completion, pipeline id)
       order, the same order the serial loop uses, so overlap changes
       host wall-clock only, never bytes (bench_sched's fleet section
       and CBENCH_FLEET_JOBS size the underlying event engine)
    -> benchmarks execute (apps::fe2ti / apps::walberla; LBM kernels
       optionally through the JAX/Pallas PJRT artifacts, runtime::)
    -> output parsed (likwid-style counters, perf::)
    -> metrics uploaded to the TSDB (tsdb::, fields+tags+trigger-time;
       time-partitioned shards, `cbench tsdb compact` rolls old shards
       up into per-series summaries for multi-year retention; the store
       persists as a manifest directory -- shard index + one file per
       shard -- loaded lazily and saved dirty-shards-only)
    -> raw files archived as linked records (datastore::, Fig. 5)
    -> dashboards + roofline plots refreshed (dashboard::, roofline::)
    -> regression check (regress::detector): every watched series is
       tested against a baseline window (Welch t, Mann-Whitney U, CUSUM
       change-point location) instead of the old last-vs-previous diff;
       the check is incremental by default (regress::state carries each
       series' rolling window across collects and ingests only the new
       points -- provably byte-identical to the full tail re-query)
    -> findings become alerts (regress::alerts): deduplicated per series,
       open -> acknowledged -> resolved, persisted as JSON next to the
       TSDB, archived as datastore records linked to the offending
       pipeline's collection, surfaced on the dashboards
    -> findings that open alerts are stamped with the alert SLA: the
       simulated cluster-time from the offending push entering the
       system to its alert opening (streaming collect bounds it by one
       pipeline's duration; batch collect pays the roster makespan)
    -> open alerts can be bisected (regress::bisect): the pipeline is
       re-run on midpoint commits to pin the first bad commit in
       O(log n) re-runs (cbench regress bisect; --campaign rebuilds the
       campaign's commit chains and bisects the alerted repository)
    -> the run itself is observable (obs::): every collect records a
       cluster-time span tree (campaign > pipeline > job > queue/run,
       collect, detect, alert-open) built purely from scheduler
       timestamps -- replaying the same roster yields a byte-identical
       trace (--save-trace; `cbench trace show|export|critical-path`;
       critical-path attributes the entire makespan, exactly, to run /
       queue-wait / maintenance / collect / idle); with --self-metrics
       on, the coordinator's own host-time throughput (line-protocol
       parse, TSDB insert, job parse, detector sync, shard load) is
       uploaded as the `cbench_self` measurement and judged by the same
       stock detector that watches the benchmarks -- an infra slowdown
       opens a regression alert like any other (alert SLAs decompose
       into queue + run + collect + detect components that sum exactly)
    -> the same core loop is servable (serve::): `cbench serve` exposes
       upload -> detect -> alert as a multi-tenant HTTP API -- each
       project owns an independent TSDB + detector state + alert book
       behind its own lock, ingests line protocol over POST, answers
       tail/range queries, and persists per-project manifest stores on
       drain; `cbench loadgen` is the matching traffic driver

Full data-flow + module map + determinism contract: ARCHITECTURE.md.
";
