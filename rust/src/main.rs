//! `cbench` launcher — CLI entry point for the continuous-benchmarking
//! infrastructure.

use cbench::cluster::microbench::{run_host_microbench, MicrobenchKind};
use cbench::cluster::nodes::{catalogue, node};
use cbench::coordinator::{fe2ti_pipeline, walberla_pipeline, CbSystem};
use cbench::dashboard::{fe2ti_dashboard, walberla_dashboard};
use cbench::report;
use cbench::tsdb::{Aggregate, Query};
use cbench::util::cli::Args;
use cbench::vcs::Repository;
use std::path::PathBuf;

fn main() {
    // die quietly when piped into `head` etc. instead of panicking
    #[cfg(unix)]
    unsafe {
        libc::signal(libc::SIGPIPE, libc::SIG_DFL);
    }
    let argv: Vec<String> = std::env::args().skip(1).collect();
    match cbench_main(argv) {
        Ok(()) => {}
        Err(e) => {
            eprintln!("error: {e:#}");
            std::process::exit(1);
        }
    }
}

fn cbench_main(argv: Vec<String>) -> anyhow::Result<()> {
    let cmd = argv.first().map(|s| s.as_str()).unwrap_or("help");
    let args = Args::parse(argv.iter().skip(1).cloned());
    match cmd {
        "help" | "--help" | "-h" => {
            println!("{HELP}");
            Ok(())
        }
        "report" => cmd_report(&args),
        "pipeline" => cmd_pipeline(&args),
        "cluster" => cmd_cluster(&args),
        "microbench" => cmd_microbench(&args),
        "dashboard" => cmd_dashboard(&args),
        "artifacts" => cmd_artifacts(&args),
        other => anyhow::bail!("unknown command `{other}` — see `cbench help`"),
    }
}

/// `cbench report <id>|all [--out DIR]` — regenerate paper tables/figures.
fn cmd_report(args: &Args) -> anyhow::Result<()> {
    let out = args.get("out").map(PathBuf::from);
    let ids: Vec<String> = match args.positional.first().map(|s| s.as_str()) {
        Some("all") | None => report::all_reports().iter().map(|s| s.to_string()).collect(),
        Some(id) => vec![id.to_string()],
    };
    for id in ids {
        println!("{}", report::run_report(&id, out.as_deref())?);
        println!();
    }
    Ok(())
}

/// `cbench pipeline <fe2ti|walberla|describe> [--commits N]` — run the CB
/// pipeline end to end on simulated commits.
fn cmd_pipeline(args: &Args) -> anyhow::Result<()> {
    let which = args.positional.first().map(|s| s.as_str()).unwrap_or("describe");
    if which == "describe" {
        println!("{PIPELINE_DESCRIPTION}");
        return Ok(());
    }
    let commits = args.get_usize("commits", 1);
    let mut cb = CbSystem::new();
    let mut repo = Repository::new(which);
    for i in 0..commits {
        let ev = repo.commit_change(
            "master",
            "dev",
            &format!("change #{i}"),
            i as f64 * 60.0,
            "src/kernel.c",
            &format!("// rev {i}\n"),
        );
        let jobs = match which {
            "fe2ti" => fe2ti_pipeline::fe2ti_pipeline_jobs(&repo, &ev.commit_id),
            "walberla" => walberla_pipeline::walberla_pipeline_jobs(&repo, &ev.commit_id),
            other => anyhow::bail!("unknown pipeline `{other}` (fe2ti|walberla)"),
        };
        let measurement = if which == "fe2ti" { "fe2ti" } else { "lbm" };
        let r = cb.execute_pipeline(&ev, which == "walberla", jobs, measurement)?;
        println!(
            "pipeline #{} commit {} jobs={} completed={} failed={} points={} records={} cluster-time={}",
            r.pipeline_id,
            &r.commit_id[..8],
            r.jobs_total,
            r.jobs_completed,
            r.jobs_failed,
            r.points_uploaded,
            r.records_created,
            cbench::util::fmt_secs(r.duration),
        );
    }
    if let Some(path) = args.get("save-tsdb") {
        cb.db.save(std::path::Path::new(path))?;
        println!("tsdb saved to {path} ({} points)", cb.db.len());
    }
    // render the project dashboard
    let dash = if which == "fe2ti" {
        fe2ti_dashboard()
    } else {
        walberla_dashboard()
    };
    println!("\n{}", dash.render_text(&cb.db));
    Ok(())
}

/// `cbench cluster [--node HOST]` — show the Testcluster catalogue.
fn cmd_cluster(args: &Args) -> anyhow::Result<()> {
    match args.get("node") {
        Some(host) => {
            let n = node(host).ok_or_else(|| anyhow::anyhow!("unknown node `{host}`"))?;
            let ms = cbench::cluster::machinestate::machine_state(&n, "inspect", 0.0);
            println!("{}", ms.to_string_pretty());
        }
        None => println!("{}", report::tables::tab2_testcluster()),
    }
    Ok(())
}

/// `cbench microbench [--n SIZE] [--reps R]` — really run the
/// likwid-bench-class kernels on this host.
fn cmd_microbench(args: &Args) -> anyhow::Result<()> {
    let n = args.get_usize("n", 1 << 22);
    let reps = args.get_usize("reps", 5);
    println!("host microbenchmarks (n={n}, reps={reps}):");
    for kind in MicrobenchKind::all() {
        let r = run_host_microbench(kind, n, reps);
        println!("  {:<10} {:>10.2} {}", kind.name(), r.value, r.unit);
    }
    println!("\nper-node projections (likwid-bench stand-in):");
    for nm in catalogue() {
        let s = cbench::cluster::microbench::project_node_microbench(&nm, MicrobenchKind::Stream);
        let p = cbench::cluster::microbench::project_node_microbench(&nm, MicrobenchKind::PeakFlops);
        println!("  {:<12} stream {:>7.0} GB/s   peak {:>7.0} GFLOP/s", nm.host, s.value, p.value);
    }
    Ok(())
}

/// `cbench dashboard <fe2ti|walberla> --tsdb FILE [--select tag=v,v]`.
fn cmd_dashboard(args: &Args) -> anyhow::Result<()> {
    let which = args.positional.first().map(|s| s.as_str()).unwrap_or("walberla");
    let tsdb = args
        .get("tsdb")
        .ok_or_else(|| anyhow::anyhow!("--tsdb FILE required (see `cbench pipeline --save-tsdb`)"))?;
    let db = cbench::tsdb::Db::load(std::path::Path::new(tsdb))?;
    let mut dash = if which == "fe2ti" {
        fe2ti_dashboard()
    } else {
        walberla_dashboard()
    };
    if let Some(sel) = args.get("select") {
        if let Some((tag, vals)) = sel.split_once('=') {
            let v: Vec<&str> = vals.split(',').collect();
            dash.select(tag, &v);
        }
    }
    println!("{}", dash.render_text(&db));
    if let Some(field) = args.get("agg") {
        let m = if which == "fe2ti" { "fe2ti" } else { "lbm" };
        for (label, v) in Query::new(m, field)
            .group_by(&["node"])
            .run_agg(&db, Aggregate::Last)
        {
            println!("{label}: {v:.4}");
        }
    }
    Ok(())
}

/// `cbench artifacts [--dir DIR]` — list + smoke the PJRT artifacts.
fn cmd_artifacts(args: &Args) -> anyhow::Result<()> {
    let dir = args.get_or("dir", "artifacts");
    let mut engine = cbench::runtime::Engine::open(dir)?;
    println!("PJRT platform: {}", engine.platform());
    let names: Vec<String> = engine.artifact_names().iter().map(|s| s.to_string()).collect();
    for name in &names {
        let meta = engine.meta(name).unwrap();
        println!(
            "  {:<24} kind={:<16} shape={:?}{}",
            name,
            meta.kind,
            meta.shape,
            meta.vmem_bytes_per_block
                .map(|v| format!(" vmem/block={}", cbench::util::fmt_bytes(v)))
                .unwrap_or_default()
        );
    }
    if args.flag("smoke") {
        let n = 8usize;
        let cells = 19 * n * n * n;
        let f = vec![1.0f32 / 19.0; cells];
        let t = std::time::Instant::now();
        let out = engine.lbm_step("lbm_d3q19_srt_8", &f)?;
        println!(
            "\nsmoke: lbm_d3q19_srt_8 executed in {} ({} values, mass drift {:.2e})",
            cbench::util::fmt_secs(t.elapsed().as_secs_f64()),
            out.len(),
            (out.iter().sum::<f32>() - f.iter().sum::<f32>()).abs()
        );
    }
    Ok(())
}

const HELP: &str = "\
cbench — continuous benchmarking infrastructure for HPC applications
(reproduction of Alt et al. 2024, DOI 10.1080/17445760.2024.2360190)

USAGE: cbench <command> [options]

COMMANDS:
  report <id>|all [--out DIR]   regenerate a paper table/figure
                                (tab1..3, fig5..fig14; side CSV/SVG with --out)
  pipeline <fe2ti|walberla>     run the CB pipeline on simulated commits
           [--commits N] [--save-tsdb FILE]
  pipeline describe             explain the pipeline wiring (Figs. 3-4)
  cluster [--node HOST]         Testcluster catalogue / machinestate dump
  microbench [--n N] [--reps R] run stream/copy/load/peakflops on this host
  dashboard <fe2ti|walberla> --tsdb FILE [--select tag=v1,v2]
                                render a dashboard from a saved TSDB
  artifacts [--dir DIR] [--smoke]
                                list + smoke-test the AOT PJRT artifacts
  help                          this help
";

const PIPELINE_DESCRIPTION: &str = "\
CB pipeline wiring (paper Figs. 3-4):

  commit pushed to repo (vcs::)
    -> pipeline triggered (ci::, proxy-repo trigger API for walberla)
    -> job matrix generated (coordinator::fe2ti_pipeline: >80 jobs =
       nodes x compilers x solvers x parallelization;
       coordinator::walberla_pipeline: 11 nodes x 4 collision ops + FSLBM)
    -> job scripts assembled (ci::assemble_job_script, Listing 1)
    -> submitted via sbatch --wait (slurm:: over cluster:: node models)
    -> benchmarks execute (apps::fe2ti / apps::walberla; LBM kernels
       optionally through the JAX/Pallas PJRT artifacts, runtime::)
    -> output parsed (likwid-style counters, perf::)
    -> metrics uploaded to the TSDB (tsdb::, fields+tags+trigger-time)
    -> raw files archived as linked records (datastore::, Fig. 5)
    -> dashboards + roofline plots refreshed (dashboard::, roofline::)
";
