//! Kadi4Mat stand-in: FAIR research-data store with records, collections
//! and typed links.
//!
//! The paper archives every pipeline execution's raw artifacts (likwid
//! output, machinestate dumps, scheduler logs) as *records* grouped into a
//! per-execution *collection*, with named links relating the records
//! (§4.3, Fig. 5). This module implements that model: records carry
//! descriptive metadata + attached files, collections group records (and
//! child collections), links are directed and named, and everything
//! exports to JSON following the FAIR findability/accessibility spirit
//! (stable IDs, rich metadata, explicit relations).

use crate::util::json::Json;
use std::collections::BTreeMap;

/// Identifier type for records/collections.
pub type Id = u64;

/// A record: arbitrary data + descriptive metadata (Kadi4Mat's basic unit).
#[derive(Debug, Clone)]
pub struct Record {
    pub id: Id,
    pub identifier: String, // human-readable unique slug
    pub title: String,
    pub record_type: String, // e.g. "likwid-output", "machinestate", "job-log"
    pub meta: BTreeMap<String, String>,
    /// Attached files: name → content.
    pub files: BTreeMap<String, String>,
}

/// A directed, named link between two records ("belongs to job",
/// "measured on", ...).
#[derive(Debug, Clone, PartialEq)]
pub struct Link {
    pub from: Id,
    pub to: Id,
    pub name: String,
}

/// A collection: a logical grouping of records; may nest child collections.
#[derive(Debug, Clone)]
pub struct Collection {
    pub id: Id,
    pub identifier: String,
    pub title: String,
    pub records: Vec<Id>,
    pub children: Vec<Id>,
}

/// The store.
#[derive(Debug, Default)]
pub struct DataStore {
    next_id: Id,
    records: BTreeMap<Id, Record>,
    collections: BTreeMap<Id, Collection>,
    links: Vec<Link>,
}

impl DataStore {
    pub fn new() -> DataStore {
        DataStore::default()
    }

    fn fresh(&mut self) -> Id {
        self.next_id += 1;
        self.next_id
    }

    pub fn create_record(
        &mut self,
        identifier: &str,
        title: &str,
        record_type: &str,
    ) -> Result<Id, String> {
        if self.records.values().any(|r| r.identifier == identifier) {
            return Err(format!("record identifier `{identifier}` already exists"));
        }
        let id = self.fresh();
        self.records.insert(
            id,
            Record {
                id,
                identifier: identifier.to_string(),
                title: title.to_string(),
                record_type: record_type.to_string(),
                meta: BTreeMap::new(),
                files: BTreeMap::new(),
            },
        );
        Ok(id)
    }

    pub fn create_collection(&mut self, identifier: &str, title: &str) -> Id {
        let id = self.fresh();
        self.collections.insert(
            id,
            Collection {
                id,
                identifier: identifier.to_string(),
                title: title.to_string(),
                records: Vec::new(),
                children: Vec::new(),
            },
        );
        id
    }

    pub fn set_meta(&mut self, record: Id, key: &str, value: &str) -> Result<(), String> {
        self.records
            .get_mut(&record)
            .ok_or_else(|| format!("no record {record}"))?
            .meta
            .insert(key.to_string(), value.to_string());
        Ok(())
    }

    pub fn attach_file(&mut self, record: Id, name: &str, content: &str) -> Result<(), String> {
        self.records
            .get_mut(&record)
            .ok_or_else(|| format!("no record {record}"))?
            .files
            .insert(name.to_string(), content.to_string());
        Ok(())
    }

    pub fn add_to_collection(&mut self, coll: Id, record: Id) -> Result<(), String> {
        if !self.records.contains_key(&record) {
            return Err(format!("no record {record}"));
        }
        let c = self
            .collections
            .get_mut(&coll)
            .ok_or_else(|| format!("no collection {coll}"))?;
        if !c.records.contains(&record) {
            c.records.push(record);
        }
        Ok(())
    }

    pub fn add_child_collection(&mut self, parent: Id, child: Id) -> Result<(), String> {
        if !self.collections.contains_key(&child) {
            return Err(format!("no collection {child}"));
        }
        let p = self
            .collections
            .get_mut(&parent)
            .ok_or_else(|| format!("no collection {parent}"))?;
        if !p.children.contains(&child) {
            p.children.push(child);
        }
        Ok(())
    }

    /// Create a named directed link between two records.
    pub fn link(&mut self, from: Id, to: Id, name: &str) -> Result<(), String> {
        if !self.records.contains_key(&from) || !self.records.contains_key(&to) {
            return Err(format!("link endpoints must exist ({from} -> {to})"));
        }
        let l = Link {
            from,
            to,
            name: name.to_string(),
        };
        if !self.links.contains(&l) {
            self.links.push(l);
        }
        Ok(())
    }

    pub fn record(&self, id: Id) -> Option<&Record> {
        self.records.get(&id)
    }
    pub fn record_by_identifier(&self, identifier: &str) -> Option<&Record> {
        self.records.values().find(|r| r.identifier == identifier)
    }
    pub fn collection(&self, id: Id) -> Option<&Collection> {
        self.collections.get(&id)
    }
    pub fn links_of(&self, record: Id) -> Vec<&Link> {
        self.links
            .iter()
            .filter(|l| l.from == record || l.to == record)
            .collect()
    }
    pub fn n_records(&self) -> usize {
        self.records.len()
    }
    pub fn n_collections(&self) -> usize {
        self.collections.len()
    }
    pub fn n_links(&self) -> usize {
        self.links.len()
    }

    /// FAIR JSON export of everything (Fig. 5's data, serialized).
    pub fn export_json(&self) -> Json {
        let mut records = Vec::new();
        for r in self.records.values() {
            let mut meta = Json::obj();
            for (k, v) in &r.meta {
                meta = meta.set(k, v.as_str());
            }
            let files: Vec<String> = r.files.keys().cloned().collect();
            records.push(
                Json::obj()
                    .set("id", r.id as i64)
                    .set("identifier", r.identifier.as_str())
                    .set("title", r.title.as_str())
                    .set("type", r.record_type.as_str())
                    .set("meta", meta)
                    .set("files", files),
            );
        }
        let mut colls = Vec::new();
        for c in self.collections.values() {
            colls.push(
                Json::obj()
                    .set("id", c.id as i64)
                    .set("identifier", c.identifier.as_str())
                    .set("title", c.title.as_str())
                    .set(
                        "records",
                        Json::Arr(c.records.iter().map(|r| Json::Num(*r as f64)).collect()),
                    )
                    .set(
                        "children",
                        Json::Arr(c.children.iter().map(|r| Json::Num(*r as f64)).collect()),
                    ),
            );
        }
        let mut links = Vec::new();
        for l in &self.links {
            links.push(
                Json::obj()
                    .set("from", l.from as i64)
                    .set("to", l.to as i64)
                    .set("name", l.name.as_str()),
            );
        }
        Json::obj()
            .set("records", Json::Arr(records))
            .set("collections", Json::Arr(colls))
            .set("links", Json::Arr(links))
    }

    /// Graphviz DOT export of the record/link graph of one collection —
    /// regenerates the Fig. 5 visualization.
    pub fn to_dot(&self, coll: Id) -> String {
        let mut out = String::from("digraph collection {\n  rankdir=LR;\n");
        if let Some(c) = self.collections.get(&coll) {
            out.push_str(&format!(
                "  label=\"{} ({})\";\n",
                c.title, c.identifier
            ));
            for rid in &c.records {
                if let Some(r) = self.records.get(rid) {
                    out.push_str(&format!(
                        "  r{} [label=\"{}\\n[{}]\"];\n",
                        r.id, r.identifier, r.record_type
                    ));
                }
            }
            for l in &self.links {
                if c.records.contains(&l.from) && c.records.contains(&l.to) {
                    out.push_str(&format!(
                        "  r{} -> r{} [label=\"{}\"];\n",
                        l.from, l.to, l.name
                    ));
                }
            }
        }
        out.push_str("}\n");
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_collections_links() {
        let mut ds = DataStore::new();
        let coll = ds.create_collection("pipeline-42", "FE2TI pipeline #42");
        let job = ds.create_record("job-icx36-ilu", "benchmark job", "job-log").unwrap();
        let likwid = ds.create_record("likwid-icx36-ilu", "likwid output", "likwid-output").unwrap();
        let ms = ds.create_record("ms-icx36-ilu", "machinestate", "machinestate").unwrap();
        for r in [job, likwid, ms] {
            ds.add_to_collection(coll, r).unwrap();
        }
        ds.link(likwid, job, "belongs to").unwrap();
        ds.link(ms, job, "belongs to").unwrap();
        ds.set_meta(job, "node", "icx36").unwrap();
        ds.attach_file(likwid, "perfctr.txt", "REGION rve ...").unwrap();

        assert_eq!(ds.n_records(), 3);
        assert_eq!(ds.n_links(), 2);
        assert_eq!(ds.links_of(job).len(), 2);
        assert_eq!(ds.collection(coll).unwrap().records.len(), 3);
        assert_eq!(
            ds.record_by_identifier("job-icx36-ilu").unwrap().meta["node"],
            "icx36"
        );
    }

    #[test]
    fn duplicate_identifier_rejected() {
        let mut ds = DataStore::new();
        ds.create_record("x", "a", "t").unwrap();
        assert!(ds.create_record("x", "b", "t").is_err());
    }

    #[test]
    fn link_requires_existing_endpoints() {
        let mut ds = DataStore::new();
        let a = ds.create_record("a", "a", "t").unwrap();
        assert!(ds.link(a, 999, "x").is_err());
        assert!(ds.link(999, a, "x").is_err());
    }

    #[test]
    fn nested_collections() {
        let mut ds = DataStore::new();
        let root = ds.create_collection("project", "project-level");
        let child = ds.create_collection("pipeline-1", "one execution");
        ds.add_child_collection(root, child).unwrap();
        assert_eq!(ds.collection(root).unwrap().children, vec![child]);
    }

    #[test]
    fn idempotent_membership_and_links() {
        let mut ds = DataStore::new();
        let c = ds.create_collection("c", "c");
        let r = ds.create_record("r", "r", "t").unwrap();
        let r2 = ds.create_record("r2", "r2", "t").unwrap();
        ds.add_to_collection(c, r).unwrap();
        ds.add_to_collection(c, r).unwrap();
        ds.link(r, r2, "l").unwrap();
        ds.link(r, r2, "l").unwrap();
        assert_eq!(ds.collection(c).unwrap().records.len(), 1);
        assert_eq!(ds.n_links(), 1);
    }

    #[test]
    fn export_json_parses_and_dot_renders() {
        let mut ds = DataStore::new();
        let coll = ds.create_collection("p", "pipeline");
        let a = ds.create_record("a", "A", "job-log").unwrap();
        let b = ds.create_record("b", "B", "likwid-output").unwrap();
        ds.add_to_collection(coll, a).unwrap();
        ds.add_to_collection(coll, b).unwrap();
        ds.link(b, a, "belongs to").unwrap();
        let j = ds.export_json();
        assert_eq!(j.get("records").unwrap().as_arr().unwrap().len(), 2);
        assert_eq!(j.get("links").unwrap().as_arr().unwrap().len(), 1);
        let dot = ds.to_dot(coll);
        // ids: collection=1, a=2, b=3; link b->a
        assert!(dot.contains("r3 -> r2"));
        assert!(dot.contains("belongs to"));
    }
}
