//! Tables 1–3 of the paper, regenerated from the live system state.

use crate::apps::fe2ti::bench::Fe2tiCase;
use crate::apps::walberla::collision::CollisionOp;
use crate::cluster::nodes::catalogue;
use crate::util::table::Table;

/// Tab. 1: comparison between the two example codes — with our stack's
/// realization next to the paper's description.
pub fn tab1_code_comparison() -> String {
    let mut t = Table::new(&["", "FE2TI", "waLBerla"]);
    t.row_str(&["Field", "material science, homogenization", "fluid dynamics"]);
    t.row_str(&["Language", "C/C++ (here: rust)", "C/C++ (here: rust + JAX/Pallas)"]);
    t.row_str(&["Algorithm", "FE^2", "LBM"]);
    t.row_str(&["Solver", "implicit", "explicit"]);
    t.row_str(&["Software architecture", "PETSc-based (here: sparse::)", "framework (here: apps::walberla)"]);
    t.row_str(&[
        "Performance critical parts",
        "RVE solver (direct or iterative)",
        "handwritten or generated kernels (here: Pallas->HLO artifacts)",
    ]);
    t.row_str(&["Parallelization", "MPI/Hybrid (with OpenMP)", "MPI/Hybrid (with OpenMP)"]);
    t.row_str(&["Accelerators", "-", "GPUs (here: modeled)"]);
    t.row_str(&["Build tool", "Make", "CMake (here: cargo + make artifacts)"]);
    format!("Table 1: Comparison between the two example codes.\n\n{}", t.render())
}

/// Tab. 2: the Testcluster node list, from the live catalogue.
pub fn tab2_testcluster() -> String {
    let mut t = Table::new(&["Hostname", "CPU", "#Cores", "Accelerators", "peak GF", "stream GB/s"]);
    for n in catalogue().into_iter().filter(|n| n.testcluster) {
        let acc = if n.accelerators.is_empty() {
            "".to_string()
        } else {
            n.accelerators
                .iter()
                .map(|a| a.name)
                .collect::<Vec<_>>()
                .join(", ")
        };
        t.row(&[
            n.host.to_string(),
            n.cpu.to_string(),
            format!("{}x {} cores", n.sockets, n.cores_per_socket),
            acc,
            format!("{:.0}", n.peak_gflops()),
            format!("{:.0}", n.stream_bw_gbs),
        ]);
    }
    format!(
        "Table 2: Compute nodes in the (simulated) Testcluster at NHR@FAU.\n\n{}",
        t.render()
    )
}

/// Tab. 3: the benchmark cases in the CB pipeline.
pub fn tab3_benchmark_cases() -> String {
    let mut t = Table::new(&["Case", "Description"]);
    t.row(&[
        Fe2tiCase::Fe2ti216.name().to_string(),
        "Deformation of dual-phase steel with 216 RVEs, different solvers and parallelization schemes".to_string(),
    ]);
    t.row(&[
        Fe2tiCase::Fe2ti1728.name().to_string(),
        "Same but with 1728 RVEs; only 216 are solved (precomputed macro solution)".to_string(),
    ]);
    let ops: Vec<&str> = CollisionOp::all().iter().map(|o| o.name()).collect();
    t.row(&[
        "UniformGrid{CPU,GPU}".to_string(),
        format!("Pure LBM on a uniform grid, D3Q27, collision operators: {}", ops.join("/")),
    ]);
    t.row(&[
        "GravityWaveFSLBM".to_string(),
        "Gravity wave solved with the free-surface LBM".to_string(),
    ]);
    format!(
        "Table 3: Benchmark cases in the continuous benchmarking pipeline.\n\n{}",
        t.render()
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tab1_mentions_both_codes() {
        let t = tab1_code_comparison();
        assert!(t.contains("FE^2") && t.contains("LBM"));
    }

    #[test]
    fn tab2_lists_all_11_nodes() {
        let t = tab2_testcluster();
        for host in ["casclakesp2", "icx36", "rome1", "genoa2", "medusa"] {
            assert!(t.contains(host), "missing {host}");
        }
        assert!(t.contains("Nvidia A40"));
    }

    #[test]
    fn tab3_lists_all_four_cases() {
        let t = tab3_benchmark_cases();
        assert!(t.contains("fe2ti216") && t.contains("fe2ti1728"));
        assert!(t.contains("UniformGrid") && t.contains("GravityWaveFSLBM"));
    }
}
