//! Report generators: one per table and figure of the paper's evaluation
//! (DESIGN.md §5 experiment index).
//!
//! Every generator returns the rendered text (tables/ASCII charts matching
//! the paper's rows and series) and optionally writes CSV/SVG/DOT files
//! when given an output directory. `cbench report <id> [--out dir]` is the
//! CLI entry point.

pub mod fe2ti_figs;
pub mod pipeline_figs;
pub mod tables;
pub mod walberla_figs;

use std::path::Path;

/// All report ids in paper order.
pub fn all_reports() -> Vec<&'static str> {
    vec![
        "tab1", "tab2", "tab3", "fig5", "fig6", "fig7", "fig8", "fig9", "fig10a", "fig10b",
        "fig11", "fig12", "fig13", "fig14",
    ]
}

/// Run one report by id. `out` receives CSV/SVG side files if set.
pub fn run_report(id: &str, out: Option<&Path>) -> anyhow::Result<String> {
    match id {
        "tab1" => Ok(tables::tab1_code_comparison()),
        "tab2" => Ok(tables::tab2_testcluster()),
        "tab3" => Ok(tables::tab3_benchmark_cases()),
        "fig5" => pipeline_figs::fig5_kadi_collection(out),
        "fig6" => pipeline_figs::fig6_lbm_dashboard(out),
        "fig7" => fe2ti_figs::fig7_roofline(out),
        "fig8" => walberla_figs::fig8_relative_performance(out),
        "fig9" => fe2ti_figs::fig9_tts_all_solvers(out),
        "fig10a" => fe2ti_figs::fig10a_flop_rates(out),
        "fig10b" => fe2ti_figs::fig10b_umfpack_blas_fix(out),
        "fig11" => fe2ti_figs::fig11_weak_scaling_fritz(out),
        "fig12" => fe2ti_figs::fig12_macro_solver_scaling(out),
        "fig13" => walberla_figs::fig13_fslbm_distribution(out),
        "fig14" => walberla_figs::fig14_fslbm_weak_scaling(out),
        other => anyhow::bail!("unknown report `{other}` — ids: {:?}", all_reports()),
    }
}

/// Helper: write a side file when an output directory is given.
pub(crate) fn side_file(out: Option<&Path>, name: &str, content: &str) -> anyhow::Result<()> {
    if let Some(dir) = out {
        std::fs::create_dir_all(dir)?;
        std::fs::write(dir.join(name), content)?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_report_id_runs() {
        // smoke: each generator produces non-empty output (no side files)
        for id in all_reports() {
            let txt = run_report(id, None).unwrap_or_else(|e| panic!("{id}: {e}"));
            assert!(txt.len() > 100, "{id}: output too short");
        }
    }

    #[test]
    fn unknown_report_errors() {
        assert!(run_report("fig99", None).is_err());
    }
}
