//! waLBerla figures: 8, 13, 14.

use super::side_file;
use crate::apps::walberla::collision::CollisionOp;
use crate::apps::walberla::fslbm::gravity_wave_phases;
use crate::apps::walberla::uniform::{Stencil, UniformGrid};
use crate::cluster::nodes::node;
use crate::cluster::WorkProfile;
use crate::mpisim::{CommModel, Geometry};
use crate::util::table::{bar_chart, series_plot, stacked_bar, Table};
use std::path::Path;

/// Fig. 8: UniformGridCPU achieved vs maximum performance on icx36 per
/// collision operator (paper: ≈80% of the stream-derived P_max).
pub fn fig8_relative_performance(out: Option<&Path>) -> anyhow::Result<String> {
    let icx = node("icx36").unwrap();
    let mut t = Table::new(&["operator", "MLUP/s", "P_max (stream)", "fraction"]);
    let mut bars = Vec::new();
    let mut csv = String::from("operator,mlups,pmax,fraction\n");
    for op in CollisionOp::all() {
        let cfg = UniformGrid::new(Stencil::D3Q27, op, 32);
        let mlups = cfg.projected_mlups(&icx);
        let pmax = cfg.pmax_mlups(&icx);
        t.row(&[
            op.name().to_string(),
            format!("{mlups:.0}"),
            format!("{pmax:.0}"),
            format!("{:.1}%", 100.0 * mlups / pmax),
        ]);
        bars.push((op.name().to_string(), mlups / pmax));
        csv.push_str(&format!("{},{mlups},{pmax},{}\n", op.name(), mlups / pmax));
    }
    side_file(out, "fig8_relative.csv", &csv)?;
    let srt = UniformGrid::new(Stencil::D3Q27, CollisionOp::Srt, 32);
    Ok(format!(
        "Figure 8: Achieved vs maximum performance (P_max = BW / bytes-per-update,\n\
         stream BW = {:.0} GB/s) for UniformGridCPU on icx36.\n\n{}\n{}\n\
         Paper check: SRT reaches ~80% of the stream-based maximum (ours: {:.0}%).\n",
        237.0,
        t.render(),
        bar_chart(&bars, 40),
        100.0 * srt.projected_mlups(&icx) / srt.pmax_mlups(&icx),
    ))
}

/// Fig. 13: FSLBM gravity-wave phase distribution per architecture.
pub fn fig13_fslbm_distribution(out: Option<&Path>) -> anyhow::Result<String> {
    let wpc = WorkProfile::new(550.0, 500.0);
    let comm = CommModel::default();
    let mut t = Table::new(&["node", "compute %", "sync %", "comm %"]);
    let mut bars = String::new();
    let mut csv = String::from("node,compute,sync,comm\n");
    for host in ["skylakesp2", "icx36", "rome1", "genoa2"] {
        let n = node(host).unwrap();
        let g = Geometry::pure_mpi(1, n.cores());
        let ph = gravity_wave_phases(&n, &g, 32, &comm, &wpc);
        let (c, s, m) = ph.shares();
        t.row(&[
            host.to_string(),
            format!("{:.1}", c * 100.0),
            format!("{:.1}", s * 100.0),
            format!("{:.1}", m * 100.0),
        ]);
        bars.push_str(&stacked_bar(host, &[("compute", c), ("sync", s), ("xchg-comm", m)], 50));
        bars.push('\n');
        csv.push_str(&format!("{host},{c},{s},{m}\n"));
    }
    side_file(out, "fig13_distribution.csv", &csv)?;
    Ok(format!(
        "Figure 13: Distribution of simulation time for GravityWaveFSLBM\n\
         (32^3 cells/core, one gravity wave per block, artificial barrier after\n\
         each computation step).\n\n{}\n{}\n\
         Paper ranges: computation 45-55%, synchronization 12-18%, communication\n\
         30-38% depending on architecture.\n",
        t.render(),
        bars
    ))
}

/// Fig. 14: FSLBM weak scaling on Fritz, 1→64 nodes, 64³ cells/core.
pub fn fig14_fslbm_weak_scaling(out: Option<&Path>) -> anyhow::Result<String> {
    let fritz = node("fritz").unwrap();
    let wpc = WorkProfile::new(550.0, 500.0);
    let comm = CommModel::default();
    let nodes_list = [1usize, 2, 4, 8, 16, 32, 64];
    let mut t = Table::new(&["nodes", "cores", "total [ms/step]", "compute", "sync", "comm"]);
    let mut csv = String::from("nodes,cores,total,compute,sync,comm\n");
    let mut total_series = Vec::new();
    let mut phase_series: Vec<(String, Vec<(f64, f64)>)> = vec![
        ("compute".into(), Vec::new()),
        ("sync".into(), Vec::new()),
        ("comm".into(), Vec::new()),
    ];
    for &nn in &nodes_list {
        let g = Geometry::pure_mpi(nn, fritz.cores());
        let ph = gravity_wave_phases(&fritz, &g, 64, &comm, &wpc);
        t.row(&[
            nn.to_string(),
            (nn * 72).to_string(),
            format!("{:.3}", ph.total() * 1e3),
            format!("{:.3}", ph.compute * 1e3),
            format!("{:.3}", ph.sync * 1e3),
            format!("{:.3}", ph.comm * 1e3),
        ]);
        csv.push_str(&format!(
            "{nn},{},{},{},{},{}\n",
            nn * 72,
            ph.total(),
            ph.compute,
            ph.sync,
            ph.comm
        ));
        let lx = (nn as f64).log2();
        total_series.push((lx, ph.total() * 1e3));
        phase_series[0].1.push((lx, ph.compute * 1e3));
        phase_series[1].1.push((lx, ph.sync * 1e3));
        phase_series[2].1.push((lx, ph.comm * 1e3));
    }
    side_file(out, "fig14_weak_scaling.csv", &csv)?;
    let plot_a = series_plot(&[("total".to_string(), total_series)], 10, 64);
    let plot_b = series_plot(&phase_series, 10, 64);
    Ok(format!(
        "Figure 14: FSLBM weak scaling on Fritz (72-4608 cores, 64^3 cells/core;\n\
         x axis log2(nodes)).\n\n{}\n(a) total time per step:\n{}\n(b) per-phase:\n{}\n\
         Paper shape: slight growth with two degradation steps — 4->8 nodes\n\
         (communication+synchronization; allocation topology) and 32->64 nodes\n\
         (synchronization only); computation scales perfectly.\n",
        t.render(),
        plot_a,
        plot_b
    ))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig8_srt_about_80_percent() {
        let icx = node("icx36").unwrap();
        let cfg = UniformGrid::new(Stencil::D3Q27, CollisionOp::Srt, 32);
        let frac = cfg.projected_mlups(&icx) / cfg.pmax_mlups(&icx);
        assert!((0.75..0.85).contains(&frac), "frac={frac}");
    }

    #[test]
    fn fig14_jump_between_4_and_8_nodes_from_comm() {
        let fritz = node("fritz").unwrap();
        let wpc = WorkProfile::new(550.0, 500.0);
        let comm = CommModel::default();
        let at = |nn: usize| {
            gravity_wave_phases(&fritz, &Geometry::pure_mpi(nn, 72), 64, &comm, &wpc)
        };
        let p4 = at(4);
        let p8 = at(8);
        let p32 = at(32);
        let p64 = at(64);
        // 4->8: comm jumps
        assert!(p8.comm > 1.1 * p4.comm, "comm {} -> {}", p4.comm, p8.comm);
        // 32->64: sync grows
        assert!(p64.sync > p32.sync);
        // compute perfectly flat (weak scaling, per-node work constant)
        assert!((p64.compute - p4.compute).abs() / p4.compute < 1e-9);
        // total grows overall
        assert!(p64.total() > at(1).total());
    }

    #[test]
    fn fig13_output_has_all_nodes() {
        let txt = fig13_fslbm_distribution(None).unwrap();
        for host in ["skylakesp2", "icx36", "rome1", "genoa2"] {
            assert!(txt.contains(host));
        }
    }
}
