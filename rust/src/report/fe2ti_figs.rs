//! FE2TI figures: 7, 9, 10a/b, 11, 12.

use super::side_file;
use crate::apps::fe2ti::bench::{run_fe2ti_benchmark, Fe2tiCase, Fe2tiRun, Parallelization};
use crate::apps::fe2ti::macroscale::{macro_solve, MacroMesh, MacroSolver};
use crate::apps::fe2ti::solvers::{BlasLib, Compiler, SolverConfig, SolverKind};
use crate::cluster::nodes::node;
use crate::cluster::WorkProfile;
use crate::mpisim::CommModel;
use crate::roofline::{roofline_svg, RooflinePoint};
use crate::util::table::{series_plot, Table};
use std::path::Path;

fn solver_matrix() -> Vec<(SolverConfig, &'static str)> {
    let mut out = Vec::new();
    for compiler in [Compiler::Intel, Compiler::Gcc] {
        for kind in SolverKind::paper_set() {
            let cfg = SolverConfig::new(kind, compiler);
            out.push((cfg, compiler.mpi()));
        }
    }
    out
}

fn bench_on(cfg: SolverConfig, host: &str, par: Parallelization) -> crate::apps::fe2ti::bench::Fe2tiRunResult {
    let n = node(host).unwrap();
    let run = Fe2tiRun::new(Fe2tiCase::Fe2ti216, cfg, par);
    run_fe2ti_benchmark(&run, &n, 1)
}

/// Fig. 7: roofline plot of one FE2TI pipeline execution on icx36.
pub fn fig7_roofline(out: Option<&Path>) -> anyhow::Result<String> {
    let icx = node("icx36").unwrap();
    let mut points = Vec::new();
    let mut t = Table::new(&["config", "oi [F/B]", "GFLOP/s", "of attainable"]);
    for (cfg, _) in solver_matrix() {
        let r = bench_on(cfg, "icx36", Parallelization::MpiOnly);
        let p = RooflinePoint {
            label: cfg.label(),
            group: cfg.kind.name(),
            oi: r.oi,
            gflops: r.gflops,
        };
        let ceil = crate::roofline::Ceilings::of(&icx);
        t.row(&[
            cfg.label(),
            format!("{:.3}", p.oi),
            format!("{:.1}", p.gflops),
            format!("{:.1}%", 100.0 * p.efficiency(&ceil)),
        ]);
        points.push(p);
    }
    let svg = roofline_svg(&icx, &points, "fe2ti216 pipeline execution");
    side_file(out, "fig7_roofline_icx36.svg", &svg)?;
    side_file(out, "fig7_points.csv", &t.to_csv())?;
    Ok(format!(
        "Figure 7: Roofline of a FE2TI pipeline execution on icx36.\n\
         (green=PARDISO, yellow=UMFPACK, blue=ILU in the SVG)\n\n{}",
        t.render()
    ))
}

/// Fig. 9: TTS of fe2ti216 for all solvers on icx36, 72 MPI ranks, over
/// a series of (identical) code revisions — stable lines per config.
pub fn fig9_tts_all_solvers(out: Option<&Path>) -> anyhow::Result<String> {
    let mut t = Table::new(&["solver", "compiler+MPI", "TTS [s]", "stable over 3 runs"]);
    let mut rows: Vec<(String, f64)> = Vec::new();
    for (cfg, mpi) in solver_matrix() {
        let runs: Vec<f64> = (0..3)
            .map(|_| bench_on(cfg, "icx36", Parallelization::MpiOnly).tts)
            .collect();
        let spread = (runs.iter().cloned().fold(f64::MIN, f64::max)
            - runs.iter().cloned().fold(f64::MAX, f64::min))
            / runs[0];
        t.row(&[
            cfg.kind.name(),
            format!("{}+{}", cfg.compiler.name(), mpi),
            format!("{:.4}", runs[0]),
            format!("spread {:.2}%", spread * 100.0),
        ]);
        rows.push((cfg.label(), runs[0]));
    }
    let mut csv = String::from("config,tts\n");
    for (l, v) in &rows {
        csv.push_str(&format!("{l},{v}\n"));
    }
    side_file(out, "fig9_tts.csv", &csv)?;

    // the paper's reading of the figure
    let get = |label: &str| rows.iter().find(|(l, _)| l == label).unwrap().1;
    let summary = format!(
        "\nShape check (paper: ILU fastest — esp. relaxed tolerance — then PARDISO,\n\
         UMFPACK/gcc slowest):\n  ilu1e-4-intel {:.4} < ilu1e-8-intel {:.4} < pardiso-intel {:.4} < umfpack-gcc {:.4}\n",
        get("ilu1e-4-intel"),
        get("ilu1e-8-intel"),
        get("pardiso-intel"),
        get("umfpack-gcc"),
    );
    Ok(format!(
        "Figure 9: TTS for fe2ti216, icx36, 72 MPI ranks, all solver configs.\n\n{}{}",
        t.render(),
        summary
    ))
}

/// Fig. 10a: FLOP rates on skylakesp2 (PARDISO highest, ILU ≈ 25 GFLOP/s).
pub fn fig10a_flop_rates(out: Option<&Path>) -> anyhow::Result<String> {
    let mut t = Table::new(&["config", "GFLOP/s", "total GFLOP", "TTS [s]"]);
    let mut csv = String::from("config,gflops,flops,tts\n");
    for (cfg, _) in solver_matrix() {
        let r = bench_on(cfg, "skylakesp2", Parallelization::MpiOnly);
        t.row(&[
            cfg.label(),
            format!("{:.1}", r.gflops),
            format!("{:.2}", r.work.flops / 1e9),
            format!("{:.4}", r.tts),
        ]);
        csv.push_str(&format!("{},{},{},{}\n", cfg.label(), r.gflops, r.work.flops, r.tts));
    }
    side_file(out, "fig10a_flops.csv", &csv)?;
    let ilu = bench_on(
        SolverConfig::new(SolverKind::Ilu { tol: 1e-4 }, Compiler::Intel),
        "skylakesp2",
        Parallelization::MpiOnly,
    );
    Ok(format!(
        "Figure 10a: Achieved FLOP rates, fe2ti216 on skylakesp2 (pure MPI).\n\n{}\n\
         Paper check: ILU reaches ≈25 GFLOP/s (ours: {:.1}); the direct solvers do more\n\
         total work; PARDISO achieves the highest rate.\n",
        t.render(),
        ilu.gflops
    ))
}

/// Fig. 10b: the UMFPACK BLAS-linkage story — TTS before/after the commit
/// that links the gcc build against BLIS.
pub fn fig10b_umfpack_blas_fix(out: Option<&Path>) -> anyhow::Result<String> {
    let before = SolverConfig::new(SolverKind::Umfpack, Compiler::Gcc); // reference BLAS
    let after = before.with_blas(BlasLib::Blis);
    let intel = SolverConfig::new(SolverKind::Umfpack, Compiler::Intel); // MKL
    let r_before = bench_on(before, "skylakesp2", Parallelization::MpiOnly);
    let r_after = bench_on(after, "skylakesp2", Parallelization::MpiOnly);
    let r_intel = bench_on(intel, "skylakesp2", Parallelization::MpiOnly);
    let mut t = Table::new(&["build", "BLAS", "TTS [s]", "GFLOP/s"]);
    t.row(&[
        "gcc (pre-fix)".into(),
        "reference".into(),
        format!("{:.4}", r_before.tts),
        format!("{:.1}", r_before.gflops),
    ]);
    t.row(&[
        "gcc (post-fix commit)".into(),
        "blis".into(),
        format!("{:.4}", r_after.tts),
        format!("{:.1}", r_after.gflops),
    ]);
    t.row(&[
        "intel".into(),
        "mkl".into(),
        format!("{:.4}", r_intel.tts),
        format!("{:.1}", r_intel.gflops),
    ]);
    side_file(out, "fig10b_umfpack.csv", &t.to_csv())?;
    Ok(format!(
        "Figure 10b: UMFPACK TTS jump when the gcc build switches from PETSc's\n\
         reference BLAS to BLIS (paper §5.1: 'it was possible to close that gap').\n\n{}\n\
         Speedup from the fix: {:.1}x (gap to intel/MKL after fix: {:.0}%).\n",
        t.render(),
        r_before.tts / r_after.tts,
        100.0 * (r_after.tts - r_intel.tts) / r_intel.tts
    ))
}

/// Weak scaling run used by Fig. 11 and the scaling pipeline: mesh grows
/// with node count, 216 RVEs per node. Returns (tts, micro, macro).
pub fn weak_scaling_point_public(
    n: &crate::cluster::nodes::NodeModel,
    nodes: usize,
    cfg: SolverConfig,
    par: Parallelization,
) -> (f64, f64, f64) {
    weak_scaling_on(n, nodes, cfg, par)
}

fn weak_scaling_point(
    host: &str,
    nodes: usize,
    cfg: SolverConfig,
    par: Parallelization,
) -> (f64, f64, f64) {
    weak_scaling_on(&node(host).unwrap(), nodes, cfg, par)
}

fn weak_scaling_on(
    n: &crate::cluster::nodes::NodeModel,
    nodes: usize,
    cfg: SolverConfig,
    par: Parallelization,
) -> (f64, f64, f64) {
    let n = n.clone();
    let mut run = Fe2tiRun::new(Fe2tiCase::Fe2ti216, cfg, par);
    // grow the macro mesh with the node count: 8 elements (216 RVEs) per node
    run.rve_n = 8;
    run.sample_rves = 1;
    let mut result = run_fe2ti_benchmark(&run, &n, nodes);
    // macro part must reflect the *global* mesh (2nodes×2×2 elements)
    let mesh = MacroMesh { ex: 2 * nodes, ey: 2, ez: 2 };
    let comm = CommModel::default();
    let geometry = par.geometry(nodes, n.cores());
    let m = macro_solve(&mesh, result.mean_stress.max(0.1), MacroSolver::SequentialDirect, &geometry, &comm)
        .expect("macro solve");
    let serial = WorkProfile::new(m.serial_work.flops, m.serial_work.bytes).parallel(0.0);
    let macro_time = (n.exec_time(&serial, 1) + m.comm_time) * result.newton_iters as f64;
    result.macro_time = macro_time;
    let tts = result.micro_time + result.omp_overhead + result.comm_time + macro_time;
    (tts, result.micro_time + result.omp_overhead, macro_time)
}

/// Fig. 11: weak scaling on Fritz, 1→64 nodes, 216 RVEs/node,
/// ILU(relaxed) + PARDISO × pure-MPI/hybrid.
pub fn fig11_weak_scaling_fritz(out: Option<&Path>) -> anyhow::Result<String> {
    let nodes_list = [1usize, 2, 4, 8, 16, 32, 64];
    let configs = [
        (SolverConfig::new(SolverKind::Ilu { tol: 1e-4 }, Compiler::Intel), Parallelization::MpiOnly, "ilu-mpi"),
        (SolverConfig::new(SolverKind::Ilu { tol: 1e-4 }, Compiler::Intel), Parallelization::Hybrid, "ilu-hybrid"),
        (SolverConfig::new(SolverKind::Pardiso, Compiler::Intel), Parallelization::MpiOnly, "pardiso-mpi"),
        (SolverConfig::new(SolverKind::Pardiso, Compiler::Intel), Parallelization::Hybrid, "pardiso-hybrid"),
    ];
    let mut t = Table::new(&["nodes", "config", "TTS [s]", "micro [s]", "macro [s]"]);
    let mut csv = String::from("nodes,config,tts,micro,macro\n");
    let mut series: Vec<(String, Vec<(f64, f64)>)> = Vec::new();
    for (cfg, par, label) in configs {
        let mut pts = Vec::new();
        for &nn in &nodes_list {
            let (tts, micro, macro_t) = weak_scaling_point("fritz", nn, cfg, par);
            t.row(&[
                nn.to_string(),
                label.to_string(),
                format!("{tts:.4}"),
                format!("{micro:.4}"),
                format!("{macro_t:.4}"),
            ]);
            csv.push_str(&format!("{nn},{label},{tts},{micro},{macro_t}\n"));
            pts.push((nn as f64, tts));
        }
        series.push((label.to_string(), pts));
    }
    side_file(out, "fig11_weak_scaling.csv", &csv)?;
    let plot = series_plot(&series, 12, 64);
    Ok(format!(
        "Figure 11: Weak scaling on Fritz, 216 RVEs/node, 1-64 nodes.\n\n{}\n{}\n\
         Paper shape: micro-solve time ≈ constant over nodes (ideal micro scaling),\n\
         TTS grows with node count (sequential macro solve), pure MPI beats hybrid\n\
         for the micro solves.\n",
        t.render(),
        plot
    ))
}

/// Fig. 12: sequential PARDISO vs parallel BDDC macro solver on JUWELS,
/// 9→900 nodes, macro-solve time summed over Newton steps.
pub fn fig12_macro_solver_scaling(out: Option<&Path>) -> anyhow::Result<String> {
    let juwels = node("juwels").unwrap();
    let comm = CommModel::default();
    let nodes_list = [9usize, 27, 100, 300, 900];
    let mut t = Table::new(&["nodes", "solver", "par", "macro time [s]"]);
    let mut csv = String::from("nodes,solver,par,macro_time\n");
    let mut series: Vec<(String, Vec<(f64, f64)>)> = Vec::new();
    for (solver, sname) in [
        (MacroSolver::SequentialDirect, "pardiso"),
        (MacroSolver::Bddc, "bddc"),
    ] {
        for par in [Parallelization::MpiOnly, Parallelization::Hybrid] {
            let mut pts = Vec::new();
            for &nn in &nodes_list {
                // 192 RVEs per node ≈ ceil(192n/27) macro elements
                let elements = (192 * nn).div_ceil(27);
                let mesh = MacroMesh { ex: elements, ey: 1, ez: 1 };
                let geometry = par.geometry(nn, juwels.cores());
                let m = macro_solve(&mesh, 1.0, solver, &geometry, &comm)
                    .map_err(|e| anyhow::anyhow!(e))?;
                let serial = WorkProfile::new(m.serial_work.flops, m.serial_work.bytes).parallel(0.0);
                let par_w = WorkProfile::new(m.parallel_work.flops, m.parallel_work.bytes).efficiency(0.4);
                // 6 macro Newton steps summed (paper sums over all steps)
                let time = 6.0
                    * (juwels.exec_time(&serial, 1)
                        + juwels.exec_time(&par_w, geometry.cores_per_node())
                        + m.comm_time);
                let _label = format!("{sname}-{}", par.name());
                t.row(&[nn.to_string(), sname.into(), par.name().into(), format!("{time:.4}")]);
                csv.push_str(&format!("{nn},{sname},{},{time}\n", par.name()));
                pts.push(((nn as f64).log10(), time));
            }
            series.push((format!("{sname}-{}", par.name()), pts));
        }
    }
    side_file(out, "fig12_macro_scaling.csv", &csv)?;
    let plot = series_plot(&series, 12, 64);
    Ok(format!(
        "Figure 12: Macroscopic solver weak scaling on JUWELS (9-900 nodes, 48\n\
         cores/node, 192 RVEs/node; x axis log10(nodes)).\n\n{}\n{}\n\
         Paper shape: sequential PARDISO macro solve grows with problem size; BDDC\n\
         stays near-flat; pure MPI wins at small node counts, hybrid beyond ~16 nodes\n\
         (communication overhead of many ranks).\n",
        t.render(),
        plot
    ))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig11_micro_time_constant_macro_grows() {
        let cfg = SolverConfig::new(SolverKind::Ilu { tol: 1e-4 }, Compiler::Intel);
        let (_, micro1, macro1) = weak_scaling_point("fritz", 1, cfg, Parallelization::MpiOnly);
        let (_, micro64, macro64) = weak_scaling_point("fritz", 64, cfg, Parallelization::MpiOnly);
        // micro-solve time ~constant (within 20%)
        assert!(
            (micro64 - micro1).abs() / micro1 < 0.2,
            "micro {micro1} -> {micro64}"
        );
        // macro solve grows substantially
        assert!(macro64 > 3.0 * macro1, "macro {macro1} -> {macro64}");
    }

    #[test]
    fn fig12_bddc_flat_pardiso_grows() {
        let juwels = node("juwels").unwrap();
        let comm = CommModel::default();
        let time_at = |nodes: usize, solver: MacroSolver| -> f64 {
            let elements = (192 * nodes).div_ceil(27);
            let mesh = MacroMesh { ex: elements, ey: 1, ez: 1 };
            let g = Parallelization::Hybrid.geometry(nodes, juwels.cores());
            let m = macro_solve(&mesh, 1.0, solver, &g, &comm).unwrap();
            let serial = WorkProfile::new(m.serial_work.flops, m.serial_work.bytes).parallel(0.0);
            let par_w = WorkProfile::new(m.parallel_work.flops, m.parallel_work.bytes).efficiency(0.4);
            juwels.exec_time(&serial, 1) + juwels.exec_time(&par_w, g.cores_per_node()) + m.comm_time
        };
        let p9 = time_at(9, MacroSolver::SequentialDirect);
        let p900 = time_at(900, MacroSolver::SequentialDirect);
        let b9 = time_at(9, MacroSolver::Bddc);
        let b900 = time_at(900, MacroSolver::Bddc);
        assert!(p900 > 10.0 * p9, "pardiso must grow: {p9} -> {p900}");
        // BDDC is much flatter than the sequential solve (the paper's
        // curve also rises slightly), and wins outright at scale
        assert!(
            b900 / b9 < 0.2 * (p900 / p9),
            "bddc growth {:.1}x should be far below pardiso growth {:.1}x",
            b900 / b9,
            p900 / p9
        );
        assert!(b900 < p900, "bddc must win at scale");
    }

    #[test]
    fn fig12_hybrid_beats_mpi_at_scale_for_pardiso() {
        // the crossover the paper explains via MPI communication overhead
        let juwels = node("juwels").unwrap();
        let comm = CommModel::default();
        let t = |nodes: usize, par: Parallelization| -> f64 {
            let elements = (192 * nodes).div_ceil(27);
            let mesh = MacroMesh { ex: elements, ey: 1, ez: 1 };
            let g = par.geometry(nodes, juwels.cores());
            let m = macro_solve(&mesh, 1.0, MacroSolver::SequentialDirect, &g, &comm).unwrap();
            let serial = WorkProfile::new(m.serial_work.flops, m.serial_work.bytes).parallel(0.0);
            juwels.exec_time(&serial, 1) + m.comm_time
        };
        assert!(
            t(900, Parallelization::Hybrid) < t(900, Parallelization::MpiOnly),
            "hybrid should win at 900 nodes"
        );
    }
}
