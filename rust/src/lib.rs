//! # cbench — Continuous Benchmarking Infrastructure for HPC Applications
//!
//! A reproduction of Alt et al., *"A Continuous Benchmarking Infrastructure
//! for High-Performance Computing Applications"* (2024), as a three-layer
//! Rust + JAX + Pallas stack:
//!
//! * **Layer 3 (this crate)** — the continuous-benchmarking coordinator:
//!   a git-like VCS, a GitLab-CI-like pipeline engine, a Slurm-like batch
//!   scheduler over a simulated heterogeneous test cluster, a likwid-like
//!   hardware-counter harness, an InfluxDB-like time-series database, a
//!   Kadi4Mat-like FAIR record store, Grafana-like dashboards and roofline
//!   analysis — plus the two instrumented HPC applications the paper
//!   benchmarks (FE2TI and waLBerla).
//! * **Layer 2 (python/compile/model.py)** — JAX compute graphs for the
//!   performance-critical kernels (LBM stream-collide, RVE CG solver),
//!   AOT-lowered to HLO text artifacts.
//! * **Layer 1 (python/compile/kernels/)** — Pallas kernels called from the
//!   L2 graphs (interpret=True on CPU), validated against pure-jnp oracles.
//!
//! Python never runs on the benchmarking path: `make artifacts` lowers the
//! kernels once, and [`runtime`] loads and executes them through PJRT.

pub mod apps;
pub mod ci;
pub mod cluster;
pub mod coordinator;
pub mod dashboard;
pub mod datastore;
pub mod mpisim;
pub mod obs;
pub mod par;
pub mod perf;
pub mod regress;
pub mod report;
pub mod roofline;
pub mod runtime;
pub mod sched;
pub mod select;
pub mod serve;
pub mod slurm;
pub mod sparse;
pub mod tsdb;
pub mod util;
pub mod vcs;
