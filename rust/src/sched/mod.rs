//! `sched::` — event-driven simulated-time scheduler for the shared
//! Testcluster.
//!
//! The seed executed one pipeline at a time: `slurm::wait_all` ran every
//! queued job to completion in FIFO order per node, so a second pipeline
//! could not touch the cluster until the first drained. This module
//! replaces that core with a discrete-event engine, the execution model
//! continuous benchmarking needs once *many* repositories share one
//! cluster (exaCB, the NEST CB study, and this paper's own >80-job
//! matrices all hit this wall):
//!
//! * a **global event queue** — a binary heap of `(time, seq)`-ordered
//!   events advancing one simulated clock across *all* nodes at once, so
//!   jobs from different pipelines interleave on the shared cluster;
//! * **per-node run slots** ([`SimScheduler::with_slots`]) — the
//!   Testcluster's single-node-exclusive partition is `slots = 1`, but
//!   shared partitions can oversubscribe;
//! * **priority + fair-share between repositories** — every submission
//!   carries an `owner` (the repository) and a `priority`
//!   ([`SubmitSpec`]); when a slot frees, the dispatcher picks the
//!   highest-priority waiting job, breaking ties toward the owner with
//!   the least consumed node-seconds, then FIFO;
//! * **completion events** ([`Completion`]) the coordinator consumes
//!   instead of a blocking `wait_all`: [`SimScheduler::step`] advances
//!   one event, [`SimScheduler::run_until_done`] advances until a given
//!   job set is terminal, [`SimScheduler::run_until_idle`] drains the
//!   queue;
//! * a **deterministic timeline** — identical submissions replay to a
//!   byte-identical event log ([`SimScheduler::timeline`]) and therefore
//!   byte-identical TSDB contents downstream; ties are broken by a
//!   monotone sequence number, never by iteration order of a hash map;
//!   fleet-scale drivers can turn the log's *formatting* off
//!   ([`SimScheduler::set_timeline`]) without touching dispatch order;
//! * **interned hot state** — nodes resolve to a dense index and
//!   fair-share owners to dense ids once at submit
//!   ([`SimScheduler::submit_at`] also defers arrivals for open-loop
//!   workloads), so the per-event path runs on vector reads with no
//!   hostname hashing or owner-string probes (see the memory-layout
//!   notes on [`SimScheduler`]);
//! * **conservative, timelimit-aware backfill** (on by default,
//!   [`SimScheduler::set_backfill`]) — when the head-of-queue job of a
//!   node cannot start (its time limit crosses a maintenance window), the
//!   dispatcher computes the head's *shadow start* (the earliest instant
//!   it could run) and slots smaller jobs into the gap, but only jobs
//!   whose **time limit** — not their unknown actual duration —
//!   guarantees they are done by the shadow start and clear of every
//!   window. Higher-priority work is never delayed: the shadow job still
//!   starts exactly when it would have with backfill off;
//! * **node maintenance windows** — [`SimScheduler::drain`] marks a node
//!   as draining from a given time (open-ended until
//!   [`SimScheduler::resume`] closes it; [`SimScheduler::maintenance`]
//!   adds a closed window directly). During a window no new job may
//!   start; running jobs finish. A job whose time limit crosses a window
//!   is not started — and in particular never backfilled — in front of
//!   it: it waits for the resume edge.
//!
//! [`crate::slurm::Scheduler`] is now a thin `sbatch --wait` veneer over
//! this engine (the paper's Listing-1 contract is unchanged), including
//! an `scontrol`-style drain/resume front end;
//! [`crate::coordinator::CbSystem`] drives it phase-split
//! (`submit_pipeline` / `collect_pipeline`) so pipelines overlap.

use crate::cluster::nodes::NodeModel;
use std::cmp::{Ordering, Reverse};
use std::collections::{BinaryHeap, HashMap};

/// Outcome a job payload reports back.
#[derive(Debug, Clone)]
pub struct JobOutcome {
    /// Simulated runtime in seconds.
    pub duration: f64,
    /// Captured stdout (the benchmark's output the pipeline parses).
    pub stdout: String,
    /// Nonzero = job failed.
    pub exit_code: i32,
}

/// The payload executed when the job starts: gets the node model and the
/// simulated start time.
pub type Payload = Box<dyn FnOnce(&NodeModel, f64) -> JobOutcome + Send>;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum JobState {
    Pending,
    Running,
    Completed,
    Failed,
    Timeout,
    Cancelled,
}

impl JobState {
    /// Terminal states: the job will never run (again).
    pub fn is_terminal(self) -> bool {
        !matches!(self, JobState::Pending | JobState::Running)
    }
}

/// Submission parameters: the `sbatch` flags plus the scheduling metadata
/// the multi-repo coordinator attaches (owner, priority, batch).
#[derive(Debug, Clone)]
pub struct SubmitSpec {
    pub name: String,
    /// `--nodelist`: the single target host.
    pub nodelist: String,
    /// `SLURM_TIMELIMIT` in minutes.
    pub timelimit_min: f64,
    /// Higher runs first among queued jobs.
    pub priority: i64,
    /// Fair-share bucket — the repository the job benchmarks for.
    pub owner: String,
    /// Grouping id (the CI pipeline id); 0 = ungrouped.
    pub batch: u64,
}

impl SubmitSpec {
    pub fn new(name: &str, nodelist: &str) -> SubmitSpec {
        SubmitSpec {
            name: name.to_string(),
            nodelist: nodelist.to_string(),
            timelimit_min: 120.0,
            priority: 0,
            owner: "default".to_string(),
            batch: 0,
        }
    }
    pub fn timelimit(mut self, minutes: f64) -> SubmitSpec {
        self.timelimit_min = minutes;
        self
    }
    pub fn priority(mut self, p: i64) -> SubmitSpec {
        self.priority = p;
        self
    }
    pub fn owner(mut self, o: &str) -> SubmitSpec {
        self.owner = o.to_string();
        self
    }
    pub fn batch(mut self, b: u64) -> SubmitSpec {
        self.batch = b;
        self
    }
}

/// Scheduler-side job record.
pub struct SimJob {
    pub id: u64,
    pub spec: SubmitSpec,
    pub state: JobState,
    pub submit_time: f64,
    pub start_time: Option<f64>,
    pub end_time: Option<f64>,
    pub log: String,
    /// True when the dispatcher backfilled this job into a gap in front
    /// of a blocked higher-priority (shadow) job.
    pub backfilled: bool,
    /// Submission order (dispatch tie-break).
    seq: u64,
    /// Position of `spec.nodelist` in the scheduler's sorted host index
    /// (resolved once at submit; the event hot path never re-hashes it).
    node_idx: usize,
    /// Interned `spec.owner` (dense id into the fair-share ledger).
    owner_id: u32,
    payload: Option<Payload>,
    /// Filled at start: the finish event applies these.
    planned_end: f64,
    planned_state: JobState,
    stdout: String,
}

impl std::fmt::Debug for SimJob {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("SimJob")
            .field("id", &self.id)
            .field("name", &self.spec.name)
            .field("node", &self.spec.nodelist)
            .field("owner", &self.spec.owner)
            .field("batch", &self.spec.batch)
            .field("state", &self.state)
            .finish()
    }
}

/// A completion event the coordinator consumes.
#[derive(Debug, Clone)]
pub struct Completion {
    pub job_id: u64,
    pub batch: u64,
    pub owner: String,
    pub name: String,
    pub node: String,
    pub state: JobState,
    pub start: f64,
    pub end: f64,
    /// The job start was a backfill, not a head-of-line dispatch.
    pub backfilled: bool,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum EventKind {
    /// A submitted job arrives at the cluster (index into `jobs`).
    Arrival(usize),
    /// A running job finishes.
    Finish(usize),
    /// Re-run dispatch on a node (index into `hosts`) — scheduled for the
    /// shadow start of a window-blocked head job or for a resume edge.
    Wake(usize),
}

/// One entry of the global event queue; total order is (time, seq) so the
/// heap pops deterministically.
#[derive(Debug, Clone, Copy)]
struct Event {
    time: f64,
    seq: u64,
    kind: EventKind,
}

impl PartialEq for Event {
    fn eq(&self, other: &Self) -> bool {
        self.seq == other.seq
    }
}
impl Eq for Event {}
impl PartialOrd for Event {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Event {
    fn cmp(&self, other: &Self) -> Ordering {
        self.time.total_cmp(&other.time).then(self.seq.cmp(&other.seq))
    }
}

/// First job id handed out (kept from the old slurm:: numbering so logs
/// and archived records read the same).
const BASE_JOB_ID: u64 = 1000;

/// The event-driven cluster scheduler: one simulated clock, all nodes.
///
/// # Memory layout
///
/// Every per-node table (`free_slots`, `waiting`, `windows`,
/// `pending_wake`, `models`) is a dense vector indexed by the node's
/// position in the sorted `hosts` index, and each job resolves its node
/// exactly once at submit; the per-event hot path (arrival → dispatch →
/// finish) never hashes or clones a hostname. Fair-share owners are
/// interned the same way: `SubmitSpec::owner` strings become dense ids
/// at submit, so the dispatch comparator reads `usage[owner_id]`
/// instead of probing a map keyed by `String` for every candidate pair.
pub struct SimScheduler {
    /// Stable node index: sorted hostnames. Every per-node vector below
    /// is aligned with it.
    hosts: Vec<String>,
    /// Node models, aligned with `hosts`.
    models: Vec<NodeModel>,
    /// Free run slots per node (by host index).
    free_slots: Vec<usize>,
    /// Jobs waiting for a slot, per node (indices into `jobs`).
    waiting: Vec<Vec<usize>>,
    /// Maintenance windows per node, `[from, until)`, sorted by `from`;
    /// `until` may be `f64::INFINITY` (open-ended drain).
    windows: Vec<Vec<(f64, f64)>>,
    /// Earliest still-pending `Wake` per node (event-pileup dedup).
    pending_wake: Vec<Option<f64>>,
    /// Timelimit-aware conservative backfill (on by default).
    backfill: bool,
    jobs: Vec<SimJob>,
    queue: BinaryHeap<Reverse<Event>>,
    clock: f64,
    event_seq: u64,
    next_id: u64,
    /// Owner interner: fair-share owner → dense id into `usage`.
    owner_ids: HashMap<String, u32>,
    /// Fair-share ledger: simulated node-seconds consumed per owner id.
    usage: Vec<f64>,
    completions: Vec<Completion>,
    timeline: Vec<String>,
    /// `false` skips all timeline formatting — fleet-scale benchmark
    /// runs keep the event engine hot without building millions of
    /// log strings ([`SimScheduler::set_timeline`]).
    timeline_on: bool,
    /// High-water mark of the event-queue depth.
    peak_queue: usize,
}

impl SimScheduler {
    /// Build a scheduler over the given nodes, one run slot per node (the
    /// Testcluster's exclusive single-node partition).
    pub fn new(nodes: Vec<NodeModel>) -> SimScheduler {
        SimScheduler::with_slots(nodes, 1)
    }

    /// Build a scheduler with `slots_per_node` concurrent run slots on
    /// every node (shared/oversubscribed partitions).
    pub fn with_slots(nodes: Vec<NodeModel>, slots_per_node: usize) -> SimScheduler {
        let slots = slots_per_node.max(1);
        let mut models = nodes;
        models.sort_by(|a, b| a.host.cmp(b.host));
        let hosts: Vec<String> = models.iter().map(|n| n.host.to_string()).collect();
        let n = hosts.len();
        SimScheduler {
            hosts,
            models,
            free_slots: vec![slots; n],
            waiting: vec![Vec::new(); n],
            windows: vec![Vec::new(); n],
            pending_wake: vec![None; n],
            backfill: true,
            jobs: Vec::new(),
            queue: BinaryHeap::new(),
            clock: 0.0,
            event_seq: 0,
            next_id: BASE_JOB_ID,
            owner_ids: HashMap::new(),
            usage: Vec::new(),
            completions: Vec::new(),
            timeline: Vec::new(),
            timeline_on: true,
            peak_queue: 0,
        }
    }

    pub fn now(&self) -> f64 {
        self.clock
    }
    /// Position of `host` in the sorted node index.
    fn host_idx(&self, host: &str) -> Option<usize> {
        self.hosts.binary_search_by(|h| h.as_str().cmp(host)).ok()
    }
    pub fn nodes(&self) -> impl Iterator<Item = &NodeModel> {
        self.models.iter()
    }
    pub fn node(&self, host: &str) -> Option<&NodeModel> {
        self.host_idx(host).map(|i| &self.models[i])
    }
    pub fn has_node(&self, host: &str) -> bool {
        self.host_idx(host).is_some()
    }

    fn idx(&self, id: u64) -> Option<usize> {
        id.checked_sub(BASE_JOB_ID)
            .map(|i| i as usize)
            .filter(|&i| i < self.jobs.len())
    }

    pub fn job(&self, id: u64) -> Option<&SimJob> {
        self.idx(id).map(|i| &self.jobs[i])
    }
    pub fn jobs(&self) -> impl Iterator<Item = &SimJob> {
        self.jobs.iter()
    }

    /// `squeue`: all jobs in the given state.
    pub fn squeue(&self, state: JobState) -> Vec<&SimJob> {
        self.jobs.iter().filter(|j| j.state == state).collect()
    }

    /// The log-file content a CI job `cat`s after completion
    /// (`${CI_JOB_NAME}.o${job_id}.log` in Listing 1).
    pub fn job_log(&self, id: u64) -> Option<&str> {
        self.job(id).map(|j| j.log.as_str())
    }

    /// Completions recorded so far, in event order (append-only; callers
    /// track their own offset to consume incrementally).
    pub fn completions(&self) -> &[Completion] {
        &self.completions
    }

    /// The deterministic event log: submissions, starts, finishes with
    /// their simulated times. Identical submissions replay to a
    /// byte-identical timeline.
    pub fn timeline(&self) -> String {
        self.timeline.join("\n")
    }

    /// Fair-share ledger: node-seconds consumed per owner so far.
    pub fn owner_usage(&self, owner: &str) -> f64 {
        self.owner_ids
            .get(owner)
            .map(|&id| self.usage[id as usize])
            .unwrap_or(0.0)
    }

    /// Number of distinct fair-share owners seen so far.
    pub fn owner_count(&self) -> usize {
        self.usage.len()
    }

    /// Enable/disable the human-readable event log (on by default).
    /// Fleet-scale benchmark drivers turn it off: a million jobs would
    /// otherwise spend most of their wall-clock formatting timeline
    /// strings nobody reads. Dispatch order, completions and all public
    /// state are unaffected — only [`SimScheduler::timeline`] comes back
    /// empty for the disabled stretch.
    pub fn set_timeline(&mut self, on: bool) {
        self.timeline_on = on;
    }
    pub fn timeline_enabled(&self) -> bool {
        self.timeline_on
    }

    /// High-water mark of the event-queue depth (submissions, finishes
    /// and wakes pending at once) — the capacity figure fleet-scale
    /// benchmarks report.
    pub fn peak_queue_depth(&self) -> usize {
        self.peak_queue
    }

    /// Enable/disable conservative backfill (on by default). Off, the
    /// dispatcher never starts a job ahead of a blocked higher-priority
    /// one — the node idles until the head job's shadow start.
    pub fn set_backfill(&mut self, on: bool) {
        self.backfill = on;
    }
    pub fn backfill_enabled(&self) -> bool {
        self.backfill
    }

    /// Maintenance windows of `host`, `[from, until)` sorted by start.
    pub fn maintenance_windows(&self, host: &str) -> &[(f64, f64)] {
        self.host_idx(host)
            .map(|i| self.windows[i].as_slice())
            .unwrap_or(&[])
    }

    /// All hostnames, sorted (the stable node index).
    pub fn hosts(&self) -> &[String] {
        &self.hosts
    }

    /// Add a closed maintenance window `[from, until)` on `host`: no new
    /// job starts inside it, and no job whose *time limit* would carry it
    /// into the window starts in front of it. Jobs already running when
    /// the window opens finish normally.
    pub fn maintenance(&mut self, host: &str, from: f64, until: f64) -> Result<(), String> {
        let Some(h) = self.host_idx(host) else {
            return Err(format!("scontrol: invalid node `{host}` (unknown host)"));
        };
        if !(from < until) {
            return Err(format!(
                "scontrol: maintenance window on `{host}` needs from < until (got {from}..{until})"
            ));
        }
        let ws = &mut self.windows[h];
        ws.push((from, until));
        ws.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.total_cmp(&b.1)));
        if self.timeline_on {
            self.timeline.push(format!(
                "t={:>12.3} drain  {host} [{from:.3}..{until:.3})",
                self.clock
            ));
        }
        Ok(())
    }

    /// `scontrol update nodename=HOST state=drain`: the node drains from
    /// `at` with no scheduled end — nothing starts on it until a matching
    /// [`SimScheduler::resume`] closes the window. Running jobs finish.
    pub fn drain(&mut self, host: &str, at: f64) -> Result<(), String> {
        self.maintenance(host, at, f64::INFINITY)
    }

    /// `scontrol update nodename=HOST state=resume`: close the open
    /// drain window of `host` at time `at` and re-arm dispatch for the
    /// resume edge.
    pub fn resume(&mut self, host: &str, at: f64) -> Result<(), String> {
        let Some(h) = self.host_idx(host) else {
            return Err(format!("scontrol: node `{host}` has no drain window"));
        };
        if self.windows[h].is_empty() {
            return Err(format!("scontrol: node `{host}` has no drain window"));
        }
        match self.windows[h].iter_mut().rev().find(|w| w.1.is_infinite()) {
            Some(w) if at > w.0 => w.1 = at,
            Some(w) => {
                return Err(format!(
                    "scontrol: resume at {at} predates the drain start {} on `{host}`",
                    w.0
                ))
            }
            None => return Err(format!("scontrol: node `{host}` has no open drain window")),
        }
        if self.timeline_on {
            self.timeline
                .push(format!("t={:>12.3} resume {host} at {at:.3}", self.clock));
        }
        // waiting jobs may have been stranded behind the open-ended
        // window (an infinite shadow schedules no wake) — re-arm dispatch
        self.schedule_wake(h, at.max(self.clock));
        Ok(())
    }

    /// Earliest time `>= t` at which a job with time limit `limit_secs`
    /// could start on `host` with `[start, start + limit_secs)` clear of
    /// every maintenance window. Conservative: the *limit*, not the
    /// (unknown at dispatch time) actual duration, decides crossing.
    /// `f64::INFINITY` when an open-ended drain blocks forever.
    pub fn earliest_start(&self, host: &str, t: f64, limit_secs: f64) -> f64 {
        match self.host_idx(host) {
            Some(h) => self.earliest_start_at(h, t, limit_secs),
            None => t,
        }
    }

    /// [`SimScheduler::earliest_start`] by host index — the dispatch
    /// hot path, no hostname lookup.
    fn earliest_start_at(&self, h: usize, t: f64, limit_secs: f64) -> f64 {
        let mut start = t;
        for &(from, until) in &self.windows[h] {
            if start >= until {
                continue;
            }
            if start + limit_secs <= from {
                break;
            }
            start = until;
            if !start.is_finite() {
                return f64::INFINITY;
            }
        }
        start
    }

    /// Schedule a `Wake` for host index `h` at `at` unless an earlier one
    /// is already pending (keeps long queues from piling up wake events).
    fn schedule_wake(&mut self, h: usize, at: f64) {
        if !at.is_finite() {
            return;
        }
        if let Some(t) = self.pending_wake[h] {
            if t > self.clock && t <= at {
                return;
            }
        }
        self.pending_wake[h] = Some(at);
        self.push_event(at, EventKind::Wake(h));
    }

    fn bump_seq(&mut self) -> u64 {
        let s = self.event_seq;
        self.event_seq += 1;
        s
    }

    fn push_event(&mut self, time: f64, kind: EventKind) {
        let seq = self.bump_seq();
        self.queue.push(Reverse(Event { time, seq, kind }));
        if self.queue.len() > self.peak_queue {
            self.peak_queue = self.queue.len();
        }
    }

    /// Queue a job. Errors if the nodelist names an unknown host (sbatch
    /// would reject it). The job arrives at the current simulated time and
    /// starts when a slot on its node frees up and the dispatcher picks it.
    pub fn submit(&mut self, spec: SubmitSpec, payload: Payload) -> Result<u64, String> {
        let now = self.clock;
        self.submit_at(spec, payload, now)
    }

    /// Queue a job whose **arrival** is deferred to simulated time `at`
    /// (clamped to the current clock): the open-loop submission model
    /// fleet-scale workloads use — a whole day of push events goes onto
    /// the event queue up front and the clock sweeps through them,
    /// instead of every job arriving at t=0 and flooding one dispatch.
    /// `submit_time` records the arrival instant.
    pub fn submit_at(&mut self, spec: SubmitSpec, payload: Payload, at: f64) -> Result<u64, String> {
        let Some(node_idx) = self.host_idx(&spec.nodelist) else {
            return Err(format!(
                "sbatch: invalid nodelist `{}` (unknown host)",
                spec.nodelist
            ));
        };
        let at = at.max(self.clock);
        let owner_id = match self.owner_ids.get(spec.owner.as_str()) {
            Some(&id) => id,
            None => {
                let id = self.usage.len() as u32;
                self.owner_ids.insert(spec.owner.clone(), id);
                self.usage.push(0.0);
                id
            }
        };
        let id = self.next_id;
        self.next_id += 1;
        let idx = self.jobs.len();
        let seq = self.bump_seq();
        if self.timeline_on {
            self.timeline.push(format!(
                "t={:>12.3} submit {} `{}` -> {} owner={} prio={} batch={}",
                at, id, spec.name, spec.nodelist, spec.owner, spec.priority, spec.batch
            ));
        }
        self.jobs.push(SimJob {
            id,
            spec,
            state: JobState::Pending,
            submit_time: at,
            start_time: None,
            end_time: None,
            log: String::new(),
            backfilled: false,
            seq,
            node_idx,
            owner_id,
            payload: Some(payload),
            planned_end: 0.0,
            planned_state: JobState::Completed,
            stdout: String::new(),
        });
        self.push_event(at, EventKind::Arrival(idx));
        Ok(id)
    }

    /// `scancel`: only jobs that have not started can be cancelled.
    pub fn scancel(&mut self, id: u64) -> bool {
        if let Some(i) = self.idx(id) {
            if self.jobs[i].state == JobState::Pending {
                self.jobs[i].state = JobState::Cancelled;
                self.jobs[i].payload = None;
                if self.timeline_on {
                    self.timeline
                        .push(format!("t={:>12.3} cancel {}", self.clock, id));
                }
                return true;
            }
        }
        false
    }

    /// Process the next event, advancing the simulated clock. Returns the
    /// event's time, or `None` when the queue is empty.
    pub fn step(&mut self) -> Option<f64> {
        let Reverse(ev) = self.queue.pop()?;
        if ev.time > self.clock {
            self.clock = ev.time;
        }
        match ev.kind {
            EventKind::Arrival(i) => {
                // cancelled before arrival: drop silently
                if self.jobs[i].state == JobState::Pending {
                    let h = self.jobs[i].node_idx;
                    self.waiting[h].push(i);
                    self.dispatch(h);
                }
            }
            EventKind::Finish(i) => {
                self.finish_job(i);
                self.dispatch(self.jobs[i].node_idx);
            }
            EventKind::Wake(h) => {
                self.pending_wake[h] = None;
                self.dispatch(h);
            }
        }
        Some(ev.time)
    }

    /// Process **every event at the next pending timestamp** — one
    /// simulated instant — and return that time (`None` on an empty
    /// queue). Events spawned at the same instant while processing (e.g.
    /// a dispatch following a finish) are included, so after the call the
    /// cluster state is consistent *between* instants. This is the
    /// streaming-collect hook: `coordinator::campaign` steps epoch by
    /// epoch and collects each pipeline at the instant its last job
    /// finished, while every tie at that instant resolves in the same
    /// deterministic `(time, seq)` order a full drain would use — the
    /// timeline stays byte-identical to batch collection.
    pub fn step_epoch(&mut self) -> Option<f64> {
        let t = self.step()?;
        while let Some(&Reverse(ev)) = self.queue.peek() {
            if ev.time > t {
                break;
            }
            self.step();
        }
        Some(t)
    }

    /// Advance until every job in `ids` reached a terminal state (or the
    /// queue drains). Other jobs' events are processed as simulated time
    /// passes them — there is one clock for the whole cluster.
    pub fn run_until_done(&mut self, ids: &[u64]) {
        while ids
            .iter()
            .any(|&id| self.job(id).map(|j| !j.state.is_terminal()).unwrap_or(false))
        {
            if self.step().is_none() {
                break;
            }
        }
    }

    /// Drain the event queue (the old `--wait` semantics). Returns the ids
    /// of jobs that finished during this call, in completion order.
    pub fn run_until_idle(&mut self) -> Vec<u64> {
        let n0 = self.completions.len();
        while self.step().is_some() {}
        self.completions[n0..].iter().map(|c| c.job_id).collect()
    }

    /// Start job `i` on its (free-slot-checked) node at the current clock.
    fn start_job(&mut self, i: usize, backfilled: bool) {
        let h = self.jobs[i].node_idx;
        self.free_slots[h] -= 1;
        let node = self.models[h].clone();
        let start = self.clock;
        let payload = self.jobs[i].payload.take().expect("pending job has payload");
        let outcome = payload(&node, start);
        let limit = self.jobs[i].spec.timelimit_min * 60.0;
        let (dur, state) = if outcome.duration > limit {
            (limit, JobState::Timeout)
        } else if outcome.exit_code != 0 {
            (outcome.duration, JobState::Failed)
        } else {
            (outcome.duration, JobState::Completed)
        };
        {
            let j = &mut self.jobs[i];
            j.state = JobState::Running;
            j.start_time = Some(start);
            j.backfilled = backfilled;
            j.planned_end = start + dur;
            j.planned_state = state;
            j.stdout = outcome.stdout;
        }
        if self.timeline_on {
            self.timeline.push(format!(
                "t={:>12.3} {} {} on {}",
                start,
                if backfilled { "bkfill" } else { "start " },
                self.jobs[i].id,
                self.hosts[h]
            ));
        }
        self.push_event(start + dur, EventKind::Finish(i));
    }

    /// Apply a finish event: state, log, fair-share ledger, completion.
    fn finish_job(&mut self, i: usize) {
        let end = self.jobs[i].planned_end;
        let state = self.jobs[i].planned_state;
        let start = self.jobs[i].start_time.unwrap_or(end);
        let h = self.jobs[i].node_idx;
        let owner_id = self.jobs[i].owner_id;
        let owner = self.jobs[i].spec.owner.clone();
        let stdout = std::mem::take(&mut self.jobs[i].stdout);
        let backfilled = self.jobs[i].backfilled;
        let (id, batch, name, submit_time) = (
            self.jobs[i].id,
            self.jobs[i].spec.batch,
            self.jobs[i].spec.name.clone(),
            self.jobs[i].submit_time,
        );
        {
            let j = &mut self.jobs[i];
            j.state = state;
            j.end_time = Some(end);
            j.log = format!(
                "== slurm job {} ({}) on {} ==\nsubmit={:.3} start={:.3} end={:.3} state={:?}\n{}{}",
                id,
                j.spec.name,
                j.spec.nodelist,
                submit_time,
                start,
                end,
                state,
                stdout,
                if state == JobState::Timeout {
                    format!("\nslurmstepd: *** JOB {id} CANCELLED DUE TO TIME LIMIT ***\n")
                } else {
                    String::new()
                }
            );
        }
        self.usage[owner_id as usize] += end - start;
        self.free_slots[h] += 1;
        if self.timeline_on {
            self.timeline.push(format!(
                "t={:>12.3} finish {} state={:?}",
                end, id, state
            ));
        }
        self.completions.push(Completion {
            job_id: id,
            batch,
            owner,
            name,
            node: self.hosts[h].clone(),
            state,
            start,
            end,
            backfilled,
        });
    }

    /// Drop `idx` from host `h`'s waiting list (it is about to start).
    fn remove_waiting(&mut self, h: usize, idx: usize) {
        let list = &mut self.waiting[h];
        if let Some(pos) = list.iter().position(|&i| i == idx) {
            list.remove(pos);
        }
    }

    /// Fill freed slots on `host` from its waiting queue: highest priority
    /// first, ties toward the owner with the least consumed node-seconds,
    /// then submission order.
    ///
    /// Maintenance windows gate every start: a job whose time limit would
    /// carry it into a window does not start in front of it. When that
    /// blocks the head-of-queue job, its *shadow start* (earliest
    /// window-clear instant) is reserved — a `Wake` re-runs dispatch
    /// there — and, with backfill enabled, lower-priority jobs whose time
    /// limit ends by the shadow start (and clears every window) are
    /// slotted into the gap. The conservative end-by-limit rule means a
    /// backfilled job can never delay the shadow job, even if it runs all
    /// the way into its timeout.
    fn dispatch(&mut self, h: usize) {
        // prune + order the waiting queue once: priority desc, fair-share
        // usage asc, submission order asc (the PR-2 comparator). All three
        // keys are invariant within one dispatch call — the clock does not
        // advance and usage only moves on finish events — so started jobs
        // are removed from this order instead of re-sorting per start.
        let mut order: Vec<usize> = {
            let jobs = &self.jobs;
            let usage = &self.usage;
            let list = &mut self.waiting[h];
            list.retain(|&i| jobs[i].state == JobState::Pending);
            if list.is_empty() {
                return;
            }
            let mut order = list.clone();
            order.sort_by(|&a, &b| {
                let (ja, jb) = (&jobs[a], &jobs[b]);
                jb.spec
                    .priority
                    .cmp(&ja.spec.priority)
                    .then_with(|| {
                        // interned owners: a dense-vector read per key,
                        // not a String-keyed map probe per comparison
                        let ua = usage[ja.owner_id as usize];
                        let ub = usage[jb.owner_id as usize];
                        ua.total_cmp(&ub)
                    })
                    .then(ja.seq.cmp(&jb.seq))
            });
            order
        };
        let mut wake_scheduled = false;
        while !order.is_empty() {
            if self.free_slots[h] == 0 {
                return;
            }
            let now = self.clock;
            let head = order[0];
            let head_limit = self.jobs[head].spec.timelimit_min * 60.0;
            let shadow = self.earliest_start_at(h, now, head_limit);
            if shadow <= now {
                self.remove_waiting(h, head);
                self.start_job(head, false);
                order.remove(0);
                continue;
            }
            // head blocked by a maintenance window: reserve its shadow
            // start (open-ended drains have no finite shadow — the resume
            // edge re-arms dispatch instead). Only the final, blocked head
            // ever reaches this point, so one wake per call suffices.
            if !wake_scheduled {
                self.schedule_wake(h, shadow);
                wake_scheduled = true;
            }
            if !self.backfill {
                return;
            }
            // conservative backfill: first (by the same order) candidate
            // whose time limit ends by the shadow start and clears every
            // window may use the gap
            let started = order.iter().skip(1).position(|&cand| {
                let limit = self.jobs[cand].spec.timelimit_min * 60.0;
                now + limit <= shadow && self.earliest_start_at(h, now, limit) <= now
            });
            match started {
                Some(pos) => {
                    let cand = order.remove(pos + 1);
                    self.remove_waiting(h, cand);
                    self.start_job(cand, true);
                }
                None => return,
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::nodes::catalogue;

    fn sched() -> SimScheduler {
        SimScheduler::new(catalogue().into_iter().filter(|n| n.testcluster).collect())
    }

    fn job(dur: f64) -> Payload {
        Box::new(move |_n, _t| JobOutcome {
            duration: dur,
            stdout: String::new(),
            exit_code: 0,
        })
    }

    #[test]
    fn events_interleave_two_batches_on_shared_nodes() {
        let mut s = sched();
        // batch 1: two 10 s jobs on icx36, one 30 s job on rome1
        let a1 = s.submit(SubmitSpec::new("a1", "icx36").batch(1), job(10.0)).unwrap();
        let a2 = s.submit(SubmitSpec::new("a2", "icx36").batch(1), job(10.0)).unwrap();
        let a3 = s.submit(SubmitSpec::new("a3", "rome1").batch(1), job(30.0)).unwrap();
        // batch 2 submitted immediately after: one icx36 job
        let b1 = s.submit(SubmitSpec::new("b1", "icx36").batch(2), job(5.0)).unwrap();
        s.run_until_idle();
        // batch 2's job ran while batch 1's rome1 job was still running —
        // the old wait_all world could not start b1 before batch 1 drained
        assert_eq!(s.job(a1).unwrap().end_time, Some(10.0));
        assert_eq!(s.job(a2).unwrap().end_time, Some(20.0));
        assert_eq!(s.job(b1).unwrap().start_time, Some(20.0));
        assert_eq!(s.job(b1).unwrap().end_time, Some(25.0));
        assert_eq!(s.job(a3).unwrap().end_time, Some(30.0));
        assert_eq!(s.now(), 30.0);
    }

    #[test]
    fn run_until_done_stops_at_target_set() {
        let mut s = sched();
        let fast = s.submit(SubmitSpec::new("fast", "icx36"), job(10.0)).unwrap();
        let slow = s.submit(SubmitSpec::new("slow", "rome1"), job(100.0)).unwrap();
        s.run_until_done(&[fast]);
        assert_eq!(s.job(fast).unwrap().state, JobState::Completed);
        // the slow job started (shared clock) but has not finished
        assert_eq!(s.job(slow).unwrap().state, JobState::Running);
        assert_eq!(s.now(), 10.0);
        s.run_until_idle();
        assert_eq!(s.job(slow).unwrap().state, JobState::Completed);
        assert_eq!(s.now(), 100.0);
    }

    #[test]
    fn step_epoch_processes_all_events_of_one_instant() {
        let mut s = sched();
        let a = s.submit(SubmitSpec::new("a", "icx36"), job(10.0)).unwrap();
        let b = s.submit(SubmitSpec::new("b", "rome1"), job(10.0)).unwrap();
        let c = s.submit(SubmitSpec::new("c", "icx36"), job(5.0)).unwrap();
        // epoch t=0: all three arrivals — a and b start, c queues
        assert_eq!(s.step_epoch(), Some(0.0));
        assert_eq!(s.job(a).unwrap().state, JobState::Running);
        assert_eq!(s.job(b).unwrap().state, JobState::Running);
        assert_eq!(s.job(c).unwrap().state, JobState::Pending);
        // epoch t=10: both finish events land in ONE epoch; c starts
        assert_eq!(s.step_epoch(), Some(10.0));
        assert!(s.job(a).unwrap().state.is_terminal());
        assert!(s.job(b).unwrap().state.is_terminal());
        assert_eq!(s.job(c).unwrap().start_time, Some(10.0));
        assert_eq!(s.step_epoch(), Some(15.0));
        assert!(s.job(c).unwrap().state.is_terminal());
        assert_eq!(s.step_epoch(), None);
    }

    #[test]
    fn epoch_stepping_replays_identically_to_full_drain() {
        // streaming collect steps epoch by epoch; the event order (and
        // thus the timeline) must be exactly what run_until_idle produces
        let build = |epochs: bool| {
            let mut s = sched();
            for i in 0..20 {
                let host = if i % 3 == 0 { "icx36" } else { "rome1" };
                s.submit(
                    SubmitSpec::new(&format!("j{i}"), host)
                        .owner(if i % 2 == 0 { "a" } else { "b" })
                        .priority((i % 4) as i64),
                    job(1.0 + (i % 5) as f64),
                )
                .unwrap();
            }
            if epochs {
                while s.step_epoch().is_some() {}
            } else {
                s.run_until_idle();
            }
            s.timeline()
        };
        assert_eq!(build(true), build(false));
    }

    #[test]
    fn priority_jumps_the_node_queue() {
        let mut s = sched();
        // filler occupies the node; low arrives before high
        let filler = s.submit(SubmitSpec::new("filler", "icx36"), job(10.0)).unwrap();
        let low = s.submit(SubmitSpec::new("low", "icx36").priority(0), job(1.0)).unwrap();
        let high = s.submit(SubmitSpec::new("high", "icx36").priority(5), job(1.0)).unwrap();
        s.run_until_idle();
        assert_eq!(s.job(filler).unwrap().end_time, Some(10.0));
        assert_eq!(s.job(high).unwrap().start_time, Some(10.0));
        assert_eq!(s.job(low).unwrap().start_time, Some(11.0));
    }

    #[test]
    fn fair_share_prefers_the_starved_owner() {
        let mut s = sched();
        // owner A floods the node; owner B submits one job last
        let a1 = s.submit(SubmitSpec::new("a1", "icx36").owner("repo-a"), job(10.0)).unwrap();
        let a2 = s.submit(SubmitSpec::new("a2", "icx36").owner("repo-a"), job(10.0)).unwrap();
        let b1 = s.submit(SubmitSpec::new("b1", "icx36").owner("repo-b"), job(10.0)).unwrap();
        s.run_until_idle();
        // after a1 finishes, repo-a has 10 node-seconds on the ledger and
        // repo-b has 0 — b1 runs before a2 despite its later submission
        assert_eq!(s.job(a1).unwrap().end_time, Some(10.0));
        assert_eq!(s.job(b1).unwrap().start_time, Some(10.0));
        assert_eq!(s.job(a2).unwrap().start_time, Some(20.0));
        assert_eq!(s.owner_usage("repo-a"), 20.0);
        assert_eq!(s.owner_usage("repo-b"), 10.0);
    }

    #[test]
    fn per_node_slots_run_concurrently() {
        let nodes: Vec<_> = catalogue().into_iter().filter(|n| n.testcluster).collect();
        let mut s = SimScheduler::with_slots(nodes, 2);
        let a = s.submit(SubmitSpec::new("a", "icx36"), job(10.0)).unwrap();
        let b = s.submit(SubmitSpec::new("b", "icx36"), job(10.0)).unwrap();
        let c = s.submit(SubmitSpec::new("c", "icx36"), job(10.0)).unwrap();
        s.run_until_idle();
        assert_eq!(s.job(a).unwrap().start_time, Some(0.0));
        assert_eq!(s.job(b).unwrap().start_time, Some(0.0));
        assert_eq!(s.job(c).unwrap().start_time, Some(10.0));
        assert_eq!(s.now(), 20.0);
    }

    #[test]
    fn timeline_is_deterministic_across_replays() {
        let build = || {
            let mut s = sched();
            for i in 0..20 {
                let host = if i % 3 == 0 { "icx36" } else { "rome1" };
                let owner = if i % 2 == 0 { "a" } else { "b" };
                s.submit(
                    SubmitSpec::new(&format!("j{i}"), host)
                        .owner(owner)
                        .priority((i % 4) as i64)
                        .batch(1 + (i % 2) as u64),
                    job(1.0 + (i % 5) as f64),
                )
                .unwrap();
            }
            s.run_until_idle();
            s.timeline()
        };
        let t1 = build();
        let t2 = build();
        assert!(!t1.is_empty());
        assert_eq!(t1, t2, "identical submissions must replay identically");
    }

    #[test]
    fn cancelled_waiting_job_is_skipped_by_dispatch() {
        let mut s = sched();
        let running = s.submit(SubmitSpec::new("r", "icx36"), job(10.0)).unwrap();
        let queued = s.submit(SubmitSpec::new("q", "icx36"), job(10.0)).unwrap();
        let after = s.submit(SubmitSpec::new("x", "icx36"), job(10.0)).unwrap();
        assert!(s.scancel(queued));
        assert!(!s.scancel(queued));
        s.run_until_idle();
        assert_eq!(s.job(queued).unwrap().state, JobState::Cancelled);
        assert_eq!(s.job(running).unwrap().state, JobState::Completed);
        // the cancelled job's slot went to the next in line
        assert_eq!(s.job(after).unwrap().start_time, Some(10.0));
    }

    #[test]
    fn completions_carry_batch_and_owner() {
        let mut s = sched();
        s.submit(SubmitSpec::new("j", "icx36").owner("walberla").batch(7), job(4.0))
            .unwrap();
        s.run_until_idle();
        let c = &s.completions()[0];
        assert_eq!(c.batch, 7);
        assert_eq!(c.owner, "walberla");
        assert_eq!(c.state, JobState::Completed);
        assert_eq!((c.start, c.end), (0.0, 4.0));
    }

    #[test]
    fn unknown_node_rejected() {
        let mut s = sched();
        assert!(s.submit(SubmitSpec::new("x", "cray-1"), job(1.0)).is_err());
    }

    #[test]
    fn no_start_inside_maintenance_window() {
        // window [10, 50): a job whose 60 s time limit crosses it cannot
        // start at t=0 and waits for the resume edge
        let mut s = sched();
        s.maintenance("icx36", 10.0, 50.0).unwrap();
        let id = s
            .submit(SubmitSpec::new("j", "icx36").timelimit(1.0), job(5.0))
            .unwrap();
        s.run_until_idle();
        let j = s.job(id).unwrap();
        assert_eq!(j.state, JobState::Completed);
        assert_eq!(j.start_time, Some(50.0));
        assert_eq!(j.end_time, Some(55.0));
        assert!(!j.backfilled);
    }

    #[test]
    fn job_fitting_before_window_starts_immediately() {
        // [start, start+limit) up to the window edge is allowed: a 6 s
        // limit ends exactly at the drain start
        let mut s = sched();
        s.maintenance("icx36", 6.0, 50.0).unwrap();
        let id = s
            .submit(SubmitSpec::new("j", "icx36").timelimit(0.1), job(5.0))
            .unwrap();
        s.run_until_idle();
        assert_eq!(s.job(id).unwrap().start_time, Some(0.0));
    }

    #[test]
    fn backfill_fills_gap_before_window_without_delaying_shadow_job() {
        // head H (priority 10, 30 min limit) crosses the [100, 1000)
        // window -> shadow start 1000; S (priority 5, 1 min limit) fits
        // the gap and backfills at t=0. H still starts exactly at 1000.
        let build = |backfill: bool| {
            let mut s = sched();
            s.set_backfill(backfill);
            s.maintenance("icx36", 100.0, 1000.0).unwrap();
            let h = s
                .submit(SubmitSpec::new("h", "icx36").timelimit(30.0).priority(10), job(200.0))
                .unwrap();
            let small = s
                .submit(SubmitSpec::new("s", "icx36").timelimit(1.0).priority(5), job(50.0))
                .unwrap();
            s.run_until_idle();
            (
                s.job(h).unwrap().start_time.unwrap(),
                s.job(small).unwrap().start_time.unwrap(),
                s.job(small).unwrap().backfilled,
                s.now(),
            )
        };
        let (h_on, s_on, s_bk, makespan_on) = build(true);
        let (h_off, s_off, s_off_bk, makespan_off) = build(false);
        assert_eq!(h_on, 1000.0, "shadow job starts at the resume edge");
        assert_eq!(h_on, h_off, "backfill must not move the shadow job");
        assert_eq!(s_on, 0.0, "small job backfills into the gap");
        assert!(s_bk);
        assert_eq!(s_off, 1250.0, "without backfill it queues behind H");
        assert!(!s_off_bk);
        assert!(
            makespan_on < makespan_off,
            "gap-heavy roster: backfill-on makespan {makespan_on} must beat {makespan_off}"
        );
    }

    #[test]
    fn backfill_candidate_crossing_the_window_is_skipped() {
        // both waiting jobs' limits cross the window: nothing backfills,
        // nothing starts inside the window, order is preserved at resume
        let mut s = sched();
        s.maintenance("icx36", 30.0, 300.0).unwrap();
        let a = s
            .submit(SubmitSpec::new("a", "icx36").timelimit(5.0).priority(1), job(10.0))
            .unwrap();
        let b = s
            .submit(SubmitSpec::new("b", "icx36").timelimit(5.0), job(10.0))
            .unwrap();
        s.run_until_idle();
        assert_eq!(s.job(a).unwrap().start_time, Some(300.0));
        assert_eq!(s.job(b).unwrap().start_time, Some(310.0));
        assert!(!s.job(a).unwrap().backfilled && !s.job(b).unwrap().backfilled);
    }

    #[test]
    fn running_job_finishes_across_a_late_drain() {
        // drain lands mid-run: the running job finishes ("running jobs
        // finish"), the queued one waits for resume
        let mut s = sched();
        let a = s
            .submit(SubmitSpec::new("a", "icx36").timelimit(2.0), job(60.0))
            .unwrap();
        let b = s
            .submit(SubmitSpec::new("b", "icx36").timelimit(2.0), job(10.0))
            .unwrap();
        // process the arrivals so `a` is running, then drain mid-run
        s.step();
        s.step();
        assert_eq!(s.job(a).unwrap().state, JobState::Running);
        s.maintenance("icx36", 30.0, 90.0).unwrap();
        s.run_until_idle();
        assert_eq!(s.job(a).unwrap().end_time, Some(60.0), "ran through the window");
        assert_eq!(s.job(b).unwrap().start_time, Some(90.0));
    }

    #[test]
    fn open_drain_strands_jobs_until_resume() {
        let mut s = sched();
        s.drain("icx36", 5.0).unwrap();
        let id = s
            .submit(SubmitSpec::new("j", "icx36").timelimit(1.0), job(10.0))
            .unwrap();
        s.run_until_idle();
        // open-ended drain: the job can never start (limit crosses it)
        assert_eq!(s.job(id).unwrap().state, JobState::Pending);
        // resume closes the window and re-arms dispatch at the edge
        s.resume("icx36", 40.0).unwrap();
        s.run_until_idle();
        assert_eq!(s.job(id).unwrap().start_time, Some(40.0));
        assert_eq!(s.job(id).unwrap().state, JobState::Completed);
        assert_eq!(s.maintenance_windows("icx36"), &[(5.0, 40.0)]);
    }

    #[test]
    fn drain_resume_validation() {
        let mut s = sched();
        assert!(s.drain("cray-1", 0.0).is_err());
        assert!(s.maintenance("icx36", 10.0, 10.0).is_err());
        assert!(s.resume("icx36", 5.0).is_err(), "no open window yet");
        s.drain("icx36", 10.0).unwrap();
        assert!(s.resume("icx36", 10.0).is_err(), "resume must be after drain");
        assert!(s.resume("icx36", 20.0).is_ok());
        assert!(s.resume("icx36", 30.0).is_err(), "window already closed");
    }

    #[test]
    fn timeline_with_windows_and_backfill_is_deterministic() {
        let build = || {
            let mut s = sched();
            s.maintenance("icx36", 40.0, 400.0).unwrap();
            s.maintenance("rome1", 100.0, 250.0).unwrap();
            for i in 0..24 {
                let host = if i % 3 == 0 { "icx36" } else { "rome1" };
                s.submit(
                    SubmitSpec::new(&format!("j{i}"), host)
                        .owner(if i % 2 == 0 { "a" } else { "b" })
                        .priority((i % 5) as i64)
                        .timelimit(0.5 + (i % 4) as f64),
                    job(3.0 + (i % 7) as f64),
                )
                .unwrap();
            }
            s.run_until_idle();
            s.timeline()
        };
        let t1 = build();
        let t2 = build();
        assert!(t1.contains("drain"));
        assert!(t1.contains("bkfill"), "gap-heavy roster must backfill");
        assert_eq!(t1, t2, "windows + backfill must replay byte-identically");
    }

    #[test]
    fn submit_at_defers_arrival_open_loop() {
        let mut s = sched();
        // arrivals at t=0, 100, 200 — the event queue sweeps through
        // them; nothing runs before its arrival instant
        let a = s.submit_at(SubmitSpec::new("a", "icx36"), job(10.0), 0.0).unwrap();
        let b = s.submit_at(SubmitSpec::new("b", "icx36"), job(10.0), 100.0).unwrap();
        let c = s.submit_at(SubmitSpec::new("c", "icx36"), job(10.0), 200.0).unwrap();
        s.run_until_idle();
        assert_eq!(s.job(a).unwrap().submit_time, 0.0);
        assert_eq!(s.job(b).unwrap().submit_time, 100.0);
        assert_eq!(s.job(b).unwrap().start_time, Some(100.0));
        assert_eq!(s.job(c).unwrap().start_time, Some(200.0));
        assert_eq!(s.now(), 210.0);
        // a past arrival clamps to the clock instead of rewinding it
        let d = s.submit_at(SubmitSpec::new("d", "icx36"), job(1.0), 5.0).unwrap();
        s.run_until_idle();
        assert_eq!(s.job(d).unwrap().submit_time, 210.0);
    }

    #[test]
    fn submit_at_now_matches_submit_byte_for_byte() {
        let build = |deferred: bool| {
            let mut s = sched();
            for i in 0..10 {
                let spec = SubmitSpec::new(&format!("j{i}"), "icx36")
                    .owner(if i % 2 == 0 { "a" } else { "b" });
                if deferred {
                    s.submit_at(spec, job(2.0 + i as f64), 0.0).unwrap();
                } else {
                    s.submit(spec, job(2.0 + i as f64)).unwrap();
                }
            }
            s.run_until_idle();
            s.timeline()
        };
        assert_eq!(build(true), build(false));
    }

    #[test]
    fn timeline_off_keeps_dispatch_identical() {
        let build = |tl: bool| {
            let mut s = sched();
            s.set_timeline(tl);
            s.maintenance("icx36", 40.0, 400.0).unwrap();
            for i in 0..16 {
                let host = if i % 3 == 0 { "icx36" } else { "rome1" };
                s.submit(
                    SubmitSpec::new(&format!("j{i}"), host)
                        .owner(if i % 2 == 0 { "a" } else { "b" })
                        .priority((i % 4) as i64)
                        .timelimit(0.5 + (i % 3) as f64),
                    job(3.0 + (i % 5) as f64),
                )
                .unwrap();
            }
            s.run_until_idle();
            let mut ends: Vec<(u64, Option<f64>)> =
                s.jobs().map(|j| (j.id, j.end_time)).collect();
            ends.sort_by(|a, b| a.0.cmp(&b.0));
            (ends, s.timeline().len())
        };
        let (on, tl_on) = build(true);
        let (off, tl_off) = build(false);
        assert_eq!(on, off, "timeline gating must not change the schedule");
        assert!(tl_on > 0 && tl_off == 0);
    }

    #[test]
    fn owner_interning_and_peak_queue_are_visible() {
        let mut s = sched();
        assert_eq!(s.owner_count(), 0);
        s.submit(SubmitSpec::new("a", "icx36").owner("x"), job(1.0)).unwrap();
        s.submit(SubmitSpec::new("b", "icx36").owner("y"), job(1.0)).unwrap();
        s.submit(SubmitSpec::new("c", "rome1").owner("x"), job(1.0)).unwrap();
        assert_eq!(s.owner_count(), 2, "owners deduplicate at submit");
        s.run_until_idle();
        assert!(s.peak_queue_depth() >= 3, "three arrivals were queued at once");
        assert_eq!(s.owner_usage("x"), 2.0);
        assert_eq!(s.owner_usage("y"), 1.0);
        assert_eq!(s.owner_usage("nobody"), 0.0);
    }

    #[test]
    fn backfilled_flag_reaches_completions() {
        let mut s = sched();
        s.maintenance("icx36", 50.0, 500.0).unwrap();
        s.submit(SubmitSpec::new("big", "icx36").timelimit(60.0).priority(9), job(20.0))
            .unwrap();
        s.submit(SubmitSpec::new("tiny", "icx36").timelimit(0.5), job(5.0))
            .unwrap();
        s.run_until_idle();
        let by_name = |n: &str| {
            s.completions()
                .iter()
                .find(|c| c.name == n)
                .unwrap()
                .clone()
        };
        assert!(by_name("tiny").backfilled);
        assert!(!by_name("big").backfilled);
    }
}
