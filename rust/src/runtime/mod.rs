//! PJRT runtime: load and execute the AOT-compiled JAX/Pallas artifacts.
//!
//! This is the rust side of the three-layer bridge (DESIGN.md §6): python
//! lowers the L2/L1 compute graphs once (`make artifacts`), and this module
//! loads `artifacts/*.hlo.txt` with `HloModuleProto::from_text_file`,
//! compiles each on the PJRT CPU client **once**, and executes from the
//! benchmark hot path. Python never runs at benchmark time.
//!
//! The waLBerla-analogue framing: the artifacts play the role of
//! lbmpy-generated kernels — authored/optimized outside the framework,
//! loaded as opaque optimized compute objects by the framework.
//!
//! **Feature gate:** actual PJRT execution needs the `xla` crate, which
//! only the rust_pallas image vendors. The default build compiles without
//! it — manifests still parse and list, but [`Engine::load`] /
//! [`Engine::execute_f32`] return an error directing to
//! `--features pjrt`. This keeps the CB stack (whose benchmark payloads
//! are modeled) buildable everywhere.

use crate::util::json::Json;
use anyhow::{anyhow, bail, Context, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// Parsed entry of `artifacts/manifest.json`.
#[derive(Debug, Clone)]
pub struct ArtifactMeta {
    pub name: String,
    pub kind: String,
    pub file: PathBuf,
    pub shape: Vec<usize>,
    /// LBM: exact collision FLOPs per lattice cell (from the L1 kernel).
    pub flops_per_cell: Option<f64>,
    /// LBM: VMEM footprint of one BlockSpec block (TPU estimate).
    pub vmem_bytes_per_block: Option<f64>,
    pub operator: Option<String>,
    pub iters: Option<usize>,
}

/// Parse `manifest.json` of an artifacts directory.
fn read_manifest(dir: &Path) -> Result<BTreeMap<String, ArtifactMeta>> {
    let man_path = dir.join("manifest.json");
    let text = std::fs::read_to_string(&man_path)
        .with_context(|| format!("reading {man_path:?} — run `make artifacts` first"))?;
    let json = Json::parse(&text).map_err(|e| anyhow!("manifest.json: {e}"))?;
    let mut meta = BTreeMap::new();
    let obj = json.as_obj().ok_or_else(|| anyhow!("manifest not an object"))?;
    for (name, m) in obj {
        let shape = m
            .get("shape")
            .and_then(|s| s.as_arr())
            .map(|a| a.iter().filter_map(|v| v.as_f64()).map(|v| v as usize).collect())
            .unwrap_or_default();
        meta.insert(
            name.clone(),
            ArtifactMeta {
                name: name.clone(),
                kind: m
                    .get("kind")
                    .and_then(|v| v.as_str())
                    .unwrap_or("unknown")
                    .to_string(),
                file: dir.join(m.get("file").and_then(|v| v.as_str()).unwrap_or("")),
                shape,
                flops_per_cell: m.get("flops_per_cell").and_then(|v| v.as_f64()),
                vmem_bytes_per_block: m.get("vmem_bytes_per_block").and_then(|v| v.as_f64()),
                operator: m.get("operator").and_then(|v| v.as_str()).map(String::from),
                iters: m.get("iters").and_then(|v| v.as_f64()).map(|v| v as usize),
            },
        );
    }
    Ok(meta)
}

/// The artifact registry: manifest + lazily compiled executables.
pub struct Engine {
    dir: PathBuf,
    meta: BTreeMap<String, ArtifactMeta>,
    #[cfg(feature = "pjrt")]
    client: xla::PjRtClient,
    #[cfg(feature = "pjrt")]
    compiled: BTreeMap<String, xla::PjRtLoadedExecutable>,
}

impl Engine {
    /// Open the artifacts directory (reads `manifest.json`).
    #[cfg(feature = "pjrt")]
    pub fn open(dir: impl AsRef<Path>) -> Result<Engine> {
        let dir = dir.as_ref().to_path_buf();
        let meta = read_manifest(&dir)?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("pjrt cpu client: {e:?}"))?;
        Ok(Engine {
            dir,
            meta,
            client,
            compiled: BTreeMap::new(),
        })
    }

    /// Open the artifacts directory (reads `manifest.json`). Without the
    /// `pjrt` feature the registry lists and inspects artifacts but
    /// cannot execute them.
    #[cfg(not(feature = "pjrt"))]
    pub fn open(dir: impl AsRef<Path>) -> Result<Engine> {
        let dir = dir.as_ref().to_path_buf();
        let meta = read_manifest(&dir)?;
        Ok(Engine { dir, meta })
    }

    pub fn platform(&self) -> String {
        #[cfg(feature = "pjrt")]
        {
            self.client.platform_name()
        }
        #[cfg(not(feature = "pjrt"))]
        {
            "unavailable (rebuild with --features pjrt)".to_string()
        }
    }
    pub fn artifact_names(&self) -> Vec<&str> {
        self.meta.keys().map(|s| s.as_str()).collect()
    }
    pub fn meta(&self, name: &str) -> Option<&ArtifactMeta> {
        self.meta.get(name)
    }
    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Compile (once) and cache the named artifact.
    #[cfg(feature = "pjrt")]
    pub fn load(&mut self, name: &str) -> Result<()> {
        if self.compiled.contains_key(name) {
            return Ok(());
        }
        let meta = self
            .meta
            .get(name)
            .ok_or_else(|| anyhow!("unknown artifact `{name}`"))?;
        let path = meta
            .file
            .to_str()
            .ok_or_else(|| anyhow!("non-utf8 path"))?
            .to_string();
        let proto = xla::HloModuleProto::from_text_file(&path)
            .map_err(|e| anyhow!("parsing {path}: {e:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {name}: {e:?}"))?;
        self.compiled.insert(name.to_string(), exe);
        Ok(())
    }

    /// Compile (once) and cache the named artifact — unavailable without
    /// the `pjrt` feature.
    #[cfg(not(feature = "pjrt"))]
    pub fn load(&mut self, name: &str) -> Result<()> {
        self.meta
            .get(name)
            .ok_or_else(|| anyhow!("unknown artifact `{name}`"))?;
        bail!("artifact `{name}` cannot be executed: built without the `pjrt` feature")
    }

    /// Execute the named artifact on f32 input buffers (shapes from the
    /// manifest or caller-provided). Returns the flattened f32 outputs of
    /// the result tuple. Host wall time is measured by the caller.
    #[cfg(feature = "pjrt")]
    pub fn execute_f32(
        &mut self,
        name: &str,
        inputs: &[(&[f32], &[usize])],
    ) -> Result<Vec<Vec<f32>>> {
        self.load(name)?;
        let exe = self.compiled.get(name).unwrap();
        let mut literals = Vec::with_capacity(inputs.len());
        for (data, shape) in inputs {
            let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
            let lit = xla::Literal::vec1(data)
                .reshape(&dims)
                .map_err(|e| anyhow!("reshape to {dims:?}: {e:?}"))?;
            literals.push(lit);
        }
        let result = exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| anyhow!("executing {name}: {e:?}"))?;
        let first = result
            .first()
            .and_then(|d| d.first())
            .ok_or_else(|| anyhow!("no output buffer"))?;
        let lit = first
            .to_literal_sync()
            .map_err(|e| anyhow!("fetching result: {e:?}"))?;
        // aot.py lowers with return_tuple=True
        let parts = lit.to_tuple().map_err(|e| anyhow!("untuple: {e:?}"))?;
        let mut out = Vec::with_capacity(parts.len());
        for p in parts {
            let v = p.to_vec::<f32>().map_err(|e| anyhow!("to_vec: {e:?}"))?;
            out.push(v);
        }
        if out.is_empty() {
            bail!("empty result tuple from {name}");
        }
        Ok(out)
    }

    /// Execute the named artifact — unavailable without the `pjrt`
    /// feature; fails with the same artifact-existence checks.
    #[cfg(not(feature = "pjrt"))]
    pub fn execute_f32(
        &mut self,
        name: &str,
        _inputs: &[(&[f32], &[usize])],
    ) -> Result<Vec<Vec<f32>>> {
        self.load(name)?;
        unreachable!("load always errors without the pjrt feature")
    }

    /// Run one LBM step artifact: `f` is the flattened (19, N, N, N) PDF
    /// field; returns the updated field.
    pub fn lbm_step(&mut self, name: &str, f: &[f32]) -> Result<Vec<f32>> {
        let shape = self
            .meta(name)
            .ok_or_else(|| anyhow!("unknown artifact `{name}`"))?
            .shape
            .clone();
        let expect: usize = shape.iter().product();
        if f.len() != expect {
            bail!("lbm_step {name}: field has {} values, artifact expects {expect}", f.len());
        }
        let mut out = self.execute_f32(name, &[(f, &shape)])?;
        Ok(out.remove(0))
    }

    /// Run an RVE CG artifact: returns (x, relative residual).
    pub fn rve_cg(&mut self, name: &str, b: &[f32], kappa: &[f32]) -> Result<(Vec<f32>, f32)> {
        let shape = self
            .meta(name)
            .ok_or_else(|| anyhow!("unknown artifact `{name}`"))?
            .shape
            .clone();
        let expect: usize = shape.iter().product();
        if b.len() != expect || kappa.len() != expect {
            bail!("rve_cg {name}: input sizes {} / {} != {expect}", b.len(), kappa.len());
        }
        let out = self.execute_f32(name, &[(b, &shape), (kappa, &shape)])?;
        if out.len() != 2 {
            bail!("rve_cg {name}: expected (x, rel), got {} outputs", out.len());
        }
        let rel = out[1].first().copied().unwrap_or(f32::NAN);
        Ok((out[0].clone(), rel))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> PathBuf {
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    fn have_artifacts() -> bool {
        artifacts_dir().join("manifest.json").exists()
    }

    #[test]
    fn manifest_parses_and_lists() {
        if !have_artifacts() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let e = Engine::open(artifacts_dir()).unwrap();
        assert!(e.artifact_names().len() >= 10);
        let m = e.meta("lbm_d3q19_srt_16").unwrap();
        assert_eq!(m.shape, vec![19, 16, 16, 16]);
        assert_eq!(m.operator.as_deref(), Some("srt"));
        assert!(m.flops_per_cell.unwrap() > 200.0);
    }

    #[test]
    #[cfg(feature = "pjrt")]
    fn lbm_step_executes_and_preserves_mass() {
        if !have_artifacts() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let mut e = Engine::open(artifacts_dir()).unwrap();
        let n = 8usize;
        let cells = 19 * n * n * n;
        // equilibrium at rest: w_q replicated per cell
        let w = [
            1.0 / 3.0,
            1.0 / 18.0, 1.0 / 18.0, 1.0 / 18.0, 1.0 / 18.0, 1.0 / 18.0, 1.0 / 18.0,
            1.0 / 36.0, 1.0 / 36.0, 1.0 / 36.0, 1.0 / 36.0, 1.0 / 36.0, 1.0 / 36.0,
            1.0 / 36.0, 1.0 / 36.0, 1.0 / 36.0, 1.0 / 36.0, 1.0 / 36.0, 1.0 / 36.0,
        ];
        let mut f = vec![0f32; cells];
        for q in 0..19 {
            for c in 0..n * n * n {
                f[q * n * n * n + c] = w[q] as f32;
            }
        }
        let mass0: f32 = f.iter().sum();
        let out = e.lbm_step("lbm_d3q19_srt_8", &f).unwrap();
        let mass1: f32 = out.iter().sum();
        assert_eq!(out.len(), cells);
        assert!((mass0 - mass1).abs() < 1e-2, "mass {mass0} -> {mass1}");
        // equilibrium at rest is a fixed point
        let max_diff = f
            .iter()
            .zip(&out)
            .map(|(a, b)| (a - b).abs())
            .fold(0f32, f32::max);
        assert!(max_diff < 1e-5, "max_diff={max_diff}");
    }

    #[test]
    #[cfg(feature = "pjrt")]
    fn rve_cg_executes_and_converges() {
        if !have_artifacts() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let mut e = Engine::open(artifacts_dir()).unwrap();
        let n = 8usize;
        let b = vec![1f32; n * n * n];
        let kappa = vec![1f32; n * n * n];
        let (x, rel) = e.rve_cg("rve_cg_8_24", &b, &kappa).unwrap();
        assert_eq!(x.len(), n * n * n);
        assert!(rel < 1e-2, "rel={rel}");
        assert!(x.iter().all(|v| v.is_finite()));
        // interior of the solution should be positive (Poisson with b>0)
        assert!(x[(n * n * n) / 2] > 0.0);
    }

    #[test]
    fn unknown_artifact_is_error() {
        if !have_artifacts() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let mut e = Engine::open(artifacts_dir()).unwrap();
        assert!(e.load("nope").is_err());
        assert!(e.lbm_step("nope", &[]).is_err());
    }

    #[test]
    fn wrong_input_size_is_error() {
        if !have_artifacts() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let mut e = Engine::open(artifacts_dir()).unwrap();
        assert!(e.lbm_step("lbm_d3q19_srt_8", &[0.0; 3]).is_err());
    }
}
