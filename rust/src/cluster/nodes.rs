//! The node catalogue: machine models for the Tab. 2 Testcluster nodes plus
//! the Fritz and JUWELS production nodes used in §5's scaling runs.
//!
//! Calibration: peak DP FLOP/s = cores × frequency × FLOP/cycle (SIMD width
//! × 2 FMA pipes where present); memory bandwidth is the STREAM-class
//! attainable number for the platform (not theoretical DDR peak). The CB
//! pipeline pins clocks to 2.0 GHz on the Testcluster (paper §5.1); Fritz
//! runs unpinned, which is why the paper's Fritz numbers are slightly
//! better — the model captures that through `freq_ghz`.

use super::WorkProfile;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Vendor {
    Intel,
    Amd,
}

/// An accelerator attached to a node (GPU). Only modeled (no execution):
/// used for the projected `UniformGridGPU` dashboard columns.
#[derive(Debug, Clone)]
pub struct Accelerator {
    pub name: &'static str,
    /// Device memory bandwidth (GB/s), the LBM-relevant ceiling.
    pub mem_bw_gbs: f64,
    pub peak_fp32_gflops: f64,
}

/// Machine model for one node type.
#[derive(Debug, Clone)]
pub struct NodeModel {
    /// Slurm hostname, e.g. `icx36`.
    pub host: &'static str,
    pub cpu: &'static str,
    pub vendor: Vendor,
    pub sockets: usize,
    pub cores_per_socket: usize,
    /// Clock the CB pipeline pins (GHz); production nodes keep turbo.
    pub freq_ghz: f64,
    /// DP FLOP per cycle per core (SIMD width × FMA pipes × 2).
    pub flops_per_cycle: f64,
    /// Attainable STREAM triad bandwidth, full node (GB/s).
    pub stream_bw_gbs: f64,
    /// copy/load variants measured by likwid-bench differ from triad;
    /// modelled as fixed ratios of stream (copy slightly lower, load higher).
    pub accelerators: Vec<Accelerator>,
    /// Whether this node is part of the single-node Testcluster partition.
    pub testcluster: bool,
}

impl NodeModel {
    pub fn cores(&self) -> usize {
        self.sockets * self.cores_per_socket
    }

    /// Peak DP GFLOP/s of the full node.
    pub fn peak_gflops(&self) -> f64 {
        self.cores() as f64 * self.freq_ghz * self.flops_per_cycle
    }

    /// Peak GFLOP/s using only `cores` cores.
    pub fn peak_gflops_cores(&self, cores: usize) -> f64 {
        cores.min(self.cores()) as f64 * self.freq_ghz * self.flops_per_cycle
    }

    /// Bandwidth attainable from `cores` cores: saturates at ~1/4 of the
    /// cores (typical for modern multi-socket machines).
    pub fn bw_gbs_cores(&self, cores: usize) -> f64 {
        let sat = (self.cores() as f64 / 4.0).max(1.0);
        let frac = (cores as f64 / sat).min(1.0);
        self.stream_bw_gbs * frac
    }

    /// Roofline execution-time projection for a counted workload on
    /// `cores` cores. Amdahl-corrected for the serial fraction.
    ///
    /// `t = max(flops / peak, bytes / bw) / efficiency`, with the parallel
    /// part using `cores` and the serial part one core.
    pub fn exec_time(&self, w: &WorkProfile, cores: usize) -> f64 {
        let cores = cores.clamp(1, self.cores());
        let eff = w.efficiency.clamp(1e-3, 1.0);
        let par = w.parallel_fraction.clamp(0.0, 1.0);

        let t_at = |c: usize, flops: f64, bytes: f64| -> f64 {
            let t_comp = flops / (self.peak_gflops_cores(c) * 1e9);
            let t_mem = bytes / (self.bw_gbs_cores(c) * 1e9);
            t_comp.max(t_mem)
        };
        let t_par = t_at(cores, w.flops * par, w.bytes * par);
        let t_ser = t_at(1, w.flops * (1.0 - par), w.bytes * (1.0 - par));
        (t_par + t_ser) / eff
    }

    /// Max LBM performance in MLUP/s given bytes moved per cell update
    /// (paper §4.5.2, after Holzer et al.: `P_max = BW / bytes_per_update`).
    pub fn lbm_pmax_mlups(&self, bytes_per_update: f64) -> f64 {
        self.stream_bw_gbs * 1e9 / bytes_per_update / 1e6
    }
}

/// Build the full catalogue: Tab. 2 Testcluster + Fritz + JUWELS.
pub fn catalogue() -> Vec<NodeModel> {
    let acc = |name: &'static str, bw: f64, pf: f64| Accelerator {
        name,
        mem_bw_gbs: bw,
        peak_fp32_gflops: pf,
    };
    vec![
        NodeModel {
            host: "casclakesp2",
            cpu: "Dual Intel Xeon Cascade Lake Gold 6248",
            vendor: Vendor::Intel,
            sockets: 2,
            cores_per_socket: 20,
            freq_ghz: 2.0,
            flops_per_cycle: 32.0, // AVX-512, 2 FMA
            stream_bw_gbs: 205.0,
            accelerators: vec![],
            testcluster: true,
        },
        NodeModel {
            host: "euryale",
            cpu: "Dual Intel Xeon Broadwell E5-2620 v4",
            vendor: Vendor::Intel,
            sockets: 2,
            cores_per_socket: 8,
            freq_ghz: 2.0,
            flops_per_cycle: 16.0, // AVX2, 2 FMA
            stream_bw_gbs: 105.0,
            accelerators: vec![acc("AMD RX 6900 XT", 512.0, 23040.0)],
            testcluster: true,
        },
        NodeModel {
            host: "genoa2",
            cpu: "Dual AMD EPYC 9354 Genoa",
            vendor: Vendor::Amd,
            sockets: 2,
            cores_per_socket: 32,
            freq_ghz: 2.0,
            flops_per_cycle: 16.0, // Zen4: AVX-512 on 2×256b datapaths
            stream_bw_gbs: 460.0,
            accelerators: vec![
                acc("Nvidia A40", 696.0, 37400.0),
                acc("Nvidia L40s", 864.0, 91600.0),
            ],
            testcluster: true,
        },
        NodeModel {
            host: "hasep1",
            cpu: "Dual Intel Xeon Haswell E5-2695 v3",
            vendor: Vendor::Intel,
            sockets: 2,
            cores_per_socket: 14,
            freq_ghz: 2.0,
            flops_per_cycle: 16.0,
            stream_bw_gbs: 112.0,
            accelerators: vec![],
            testcluster: true,
        },
        NodeModel {
            host: "icx36",
            cpu: "Dual Intel Xeon Ice Lake Platinum 8360Y",
            vendor: Vendor::Intel,
            sockets: 2,
            cores_per_socket: 36,
            freq_ghz: 2.0,
            flops_per_cycle: 32.0,
            stream_bw_gbs: 237.0, // paper §5.2 quotes ≈237 GB/s stream
            accelerators: vec![],
            testcluster: true,
        },
        NodeModel {
            host: "ivyep1",
            cpu: "Dual Intel Xeon Ivy Bridge E5-2690 v2",
            vendor: Vendor::Intel,
            sockets: 2,
            cores_per_socket: 10,
            freq_ghz: 2.0,
            flops_per_cycle: 8.0, // AVX, no FMA
            stream_bw_gbs: 85.0,
            accelerators: vec![],
            testcluster: true,
        },
        NodeModel {
            host: "medusa",
            cpu: "Dual Intel Xeon Cascade Lake Gold 6246",
            vendor: Vendor::Intel,
            sockets: 2,
            cores_per_socket: 12,
            freq_ghz: 2.0,
            flops_per_cycle: 32.0,
            stream_bw_gbs: 200.0,
            accelerators: vec![
                acc("Nvidia Geforce RTX 2070 SUPER", 448.0, 9060.0),
                acc("Nvidia Geforce RTX 2080 SUPER", 496.0, 11150.0),
                acc("Nvidia Quadro RTX 5000", 448.0, 11150.0),
                acc("Nvidia Quadro RTX 6000", 672.0, 16300.0),
            ],
            testcluster: true,
        },
        NodeModel {
            host: "naples1",
            cpu: "Dual AMD EPYC 7451 Naples",
            vendor: Vendor::Amd,
            sockets: 2,
            cores_per_socket: 24,
            freq_ghz: 2.0,
            flops_per_cycle: 8.0, // Zen1: 2×128b FMA
            stream_bw_gbs: 230.0,
            accelerators: vec![],
            testcluster: true,
        },
        NodeModel {
            host: "optane1",
            cpu: "Dual Intel Xeon Ice Lake Platinum 8362",
            vendor: Vendor::Intel,
            sockets: 2,
            cores_per_socket: 32,
            freq_ghz: 2.0,
            flops_per_cycle: 32.0,
            stream_bw_gbs: 230.0,
            accelerators: vec![],
            testcluster: true,
        },
        NodeModel {
            host: "rome1",
            cpu: "Single AMD EPYC 7452 Rome",
            vendor: Vendor::Amd,
            sockets: 1,
            cores_per_socket: 32,
            freq_ghz: 2.0,
            flops_per_cycle: 16.0, // Zen2: 2×256b FMA
            stream_bw_gbs: 120.0,
            accelerators: vec![],
            testcluster: true,
        },
        NodeModel {
            host: "skylakesp2",
            cpu: "Intel Xeon Skylake Gold 6148",
            vendor: Vendor::Intel,
            sockets: 2,
            cores_per_socket: 20,
            freq_ghz: 2.0,
            flops_per_cycle: 32.0,
            stream_bw_gbs: 180.0,
            accelerators: vec![],
            testcluster: true,
        },
        // ---- production systems for the §5 scaling runs ----
        NodeModel {
            host: "fritz",
            cpu: "Dual Intel Xeon Ice Lake Platinum 8360Y (Fritz @ NHR@FAU)",
            vendor: Vendor::Intel,
            sockets: 2,
            cores_per_socket: 36,
            freq_ghz: 2.3, // not pinned → slightly faster than icx36 (paper §5.1)
            flops_per_cycle: 32.0,
            stream_bw_gbs: 250.0,
            accelerators: vec![],
            testcluster: false,
        },
        NodeModel {
            host: "juwels",
            cpu: "Dual Intel Xeon Skylake Platinum 8168 (JUWELS @ JSC)",
            vendor: Vendor::Intel,
            sockets: 2,
            cores_per_socket: 24,
            freq_ghz: 2.2,
            flops_per_cycle: 32.0,
            stream_bw_gbs: 190.0,
            accelerators: vec![],
            testcluster: false,
        },
    ]
}

/// Look up a node model by hostname.
pub fn node(host: &str) -> Option<NodeModel> {
    catalogue().into_iter().find(|n| n.host == host)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn catalogue_covers_table2_plus_production() {
        let cat = catalogue();
        let hosts: Vec<&str> = cat.iter().map(|n| n.host).collect();
        for h in [
            "casclakesp2", "euryale", "genoa2", "hasep1", "icx36", "ivyep1",
            "medusa", "naples1", "optane1", "rome1", "skylakesp2",
        ] {
            assert!(hosts.contains(&h), "missing Tab.2 host {h}");
        }
        assert!(hosts.contains(&"fritz") && hosts.contains(&"juwels"));
        assert_eq!(cat.iter().filter(|n| n.testcluster).count(), 11);
    }

    #[test]
    fn icx36_matches_paper_quotes() {
        let n = node("icx36").unwrap();
        assert_eq!(n.cores(), 72);
        // paper: ≈237 GB/s stream on the Icelake node
        assert!((n.stream_bw_gbs - 237.0).abs() < 1.0);
        // 72 cores × 2.0 GHz × 32 flop/cy = 4608 GF
        assert!((n.peak_gflops() - 4608.0).abs() < 1.0);
    }

    #[test]
    fn exec_time_respects_roofline() {
        let n = node("icx36").unwrap();
        // pure-compute workload: 4.608e12 flops at peak = 1 s on full node
        let w = WorkProfile::new(4.608e12, 0.0);
        let t = n.exec_time(&w, 72);
        assert!((t - 1.0).abs() < 1e-9, "t={t}");
        // memory-bound workload: 237 GB at full BW = 1 s
        let w = WorkProfile::new(0.0, 237e9);
        assert!((n.exec_time(&w, 72) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn exec_time_scales_with_cores_and_efficiency() {
        let n = node("icx36").unwrap();
        let w = WorkProfile::new(1e12, 0.0);
        let t72 = n.exec_time(&w, 72);
        let t36 = n.exec_time(&w, 36);
        assert!((t36 / t72 - 2.0).abs() < 1e-9);
        let w_half = WorkProfile::new(1e12, 0.0).efficiency(0.5);
        assert!((n.exec_time(&w_half, 72) / t72 - 2.0).abs() < 1e-9);
    }

    #[test]
    fn serial_fraction_dominates_amdahl() {
        let n = node("icx36").unwrap();
        let w = WorkProfile::new(1e12, 0.0).parallel(0.5);
        let t = n.exec_time(&w, 72);
        // serial half on 1 core ≈ 0.5e12/64e9 = 7.8 s >> parallel half
        assert!(t > 7.0, "t={t}");
    }

    #[test]
    fn bandwidth_saturates() {
        let n = node("icx36").unwrap();
        // 18 cores (= cores/4) already saturate
        assert_eq!(n.bw_gbs_cores(18), n.bw_gbs_cores(72));
        assert!(n.bw_gbs_cores(1) < n.bw_gbs_cores(18));
    }

    #[test]
    fn lbm_pmax_matches_formula() {
        let n = node("icx36").unwrap();
        // D3Q19 AA-even-ish: 19 reads + 19 writes × 8 B = 304 B/update
        let p = n.lbm_pmax_mlups(304.0);
        assert!((p - 237e9 / 304.0 / 1e6).abs() < 1e-6);
    }

    #[test]
    fn fritz_faster_than_pinned_icx36() {
        let f = node("fritz").unwrap();
        let i = node("icx36").unwrap();
        assert!(f.peak_gflops() > i.peak_gflops());
    }

    #[test]
    fn gpu_nodes_have_accelerators() {
        assert_eq!(node("medusa").unwrap().accelerators.len(), 4);
        assert_eq!(node("genoa2").unwrap().accelerators.len(), 2);
        assert!(node("icx36").unwrap().accelerators.is_empty());
    }
}
