//! likwid-bench stand-in: stream / copy / load / peakflops microbenchmarks.
//!
//! The paper measures per-node memory bandwidth and peak FLOP/s with
//! `likwid-bench` and stores them in the TSDB as the roofline ceilings
//! (§4.4). Here the kernels are **really executed on the host** (so the
//! numbers are honest measurements of this machine) and additionally
//! **projected per node model** for the simulated cluster's dashboards.

use super::nodes::NodeModel;
use std::time::Instant;

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum MicrobenchKind {
    /// triad: a[i] = b[i] + s*c[i]  (3 streams)
    Stream,
    /// copy: a[i] = b[i]            (2 streams)
    Copy,
    /// load: s += a[i]              (1 stream)
    Load,
    /// peakflops: fused multiply-add chain, cache-resident
    PeakFlops,
}

impl MicrobenchKind {
    pub fn all() -> [MicrobenchKind; 4] {
        [
            MicrobenchKind::Stream,
            MicrobenchKind::Copy,
            MicrobenchKind::Load,
            MicrobenchKind::PeakFlops,
        ]
    }
    pub fn name(self) -> &'static str {
        match self {
            MicrobenchKind::Stream => "stream",
            MicrobenchKind::Copy => "copy",
            MicrobenchKind::Load => "load",
            MicrobenchKind::PeakFlops => "peakflops",
        }
    }
    /// Ratio of this benchmark's attainable bandwidth to stream triad —
    /// calibration constants reflecting typical likwid-bench spreads.
    pub fn bw_ratio(self) -> f64 {
        match self {
            MicrobenchKind::Stream => 1.0,
            MicrobenchKind::Copy => 0.92,
            MicrobenchKind::Load => 1.08,
            MicrobenchKind::PeakFlops => 0.0,
        }
    }
}

#[derive(Debug, Clone)]
pub struct MicrobenchResult {
    pub kind: MicrobenchKind,
    /// GB/s for the bandwidth kernels, GFLOP/s for peakflops.
    pub value: f64,
    pub unit: &'static str,
    /// true if really measured on the host, false if projected from model.
    pub measured: bool,
}

/// Really run the microbenchmark kernel on the host and report the
/// measured number. `n` is the working-set length in f64 elements.
pub fn run_host_microbench(kind: MicrobenchKind, n: usize, reps: usize) -> MicrobenchResult {
    match kind {
        MicrobenchKind::Stream => {
            let b = vec![1.0f64; n];
            let c = vec![2.0f64; n];
            let mut a = vec![0.0f64; n];
            let s = 1.5f64;
            let t = Instant::now();
            for _ in 0..reps {
                for i in 0..n {
                    a[i] = b[i] + s * c[i];
                }
                std::hint::black_box(&mut a);
            }
            let secs = t.elapsed().as_secs_f64();
            let bytes = (3 * 8 * n * reps) as f64;
            MicrobenchResult {
                kind,
                value: bytes / secs / 1e9,
                unit: "GB/s",
                measured: true,
            }
        }
        MicrobenchKind::Copy => {
            let b = vec![1.0f64; n];
            let mut a = vec![0.0f64; n];
            let t = Instant::now();
            for _ in 0..reps {
                a.copy_from_slice(&b);
                std::hint::black_box(&mut a);
            }
            let secs = t.elapsed().as_secs_f64();
            let bytes = (2 * 8 * n * reps) as f64;
            MicrobenchResult {
                kind,
                value: bytes / secs / 1e9,
                unit: "GB/s",
                measured: true,
            }
        }
        MicrobenchKind::Load => {
            let a = vec![1.0f64; n];
            let mut acc = 0.0f64;
            let t = Instant::now();
            for _ in 0..reps {
                let mut s0 = 0.0;
                let mut s1 = 0.0;
                let mut s2 = 0.0;
                let mut s3 = 0.0;
                let mut i = 0;
                while i + 4 <= n {
                    s0 += a[i];
                    s1 += a[i + 1];
                    s2 += a[i + 2];
                    s3 += a[i + 3];
                    i += 4;
                }
                acc += s0 + s1 + s2 + s3;
            }
            std::hint::black_box(acc);
            let secs = t.elapsed().as_secs_f64();
            let bytes = (8 * n * reps) as f64;
            MicrobenchResult {
                kind,
                value: bytes / secs / 1e9,
                unit: "GB/s",
                measured: true,
            }
        }
        MicrobenchKind::PeakFlops => {
            // cache-resident FMA chains, 8 accumulators
            let m = n.min(4096);
            let a = vec![1.000000001f64; m];
            let mut acc = [1.0f64; 8];
            let t = Instant::now();
            for _ in 0..reps {
                for i in (0..m).step_by(8) {
                    for (k, acc_k) in acc.iter_mut().enumerate() {
                        let x = a[(i + k) % m];
                        *acc_k = acc_k.mul_add(x, 0.5);
                    }
                }
            }
            std::hint::black_box(acc);
            let secs = t.elapsed().as_secs_f64();
            let flops = (2 * m * reps) as f64; // each FMA = 2 flops
            MicrobenchResult {
                kind,
                value: flops / secs / 1e9,
                unit: "GFLOP/s",
                measured: true,
            }
        }
    }
}

/// Project the microbenchmark result for a catalogue node (what
/// likwid-bench would report on that machine). Used to fill the roofline
/// ceilings for all 11 Testcluster architectures.
pub fn project_node_microbench(node: &NodeModel, kind: MicrobenchKind) -> MicrobenchResult {
    let value = match kind {
        MicrobenchKind::PeakFlops => node.peak_gflops(),
        bw => node.stream_bw_gbs * bw.bw_ratio(),
    };
    MicrobenchResult {
        kind,
        value,
        unit: if kind == MicrobenchKind::PeakFlops {
            "GFLOP/s"
        } else {
            "GB/s"
        },
        measured: false,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::nodes::node;

    #[test]
    fn host_microbenches_produce_positive_numbers() {
        for kind in MicrobenchKind::all() {
            let r = run_host_microbench(kind, 1 << 16, 4);
            assert!(r.value > 0.0, "{:?} -> {}", kind, r.value);
            assert!(r.measured);
        }
    }

    #[test]
    fn projection_uses_node_model() {
        let n = node("icx36").unwrap();
        let s = project_node_microbench(&n, MicrobenchKind::Stream);
        assert_eq!(s.value, 237.0);
        let p = project_node_microbench(&n, MicrobenchKind::PeakFlops);
        assert_eq!(p.value, n.peak_gflops());
        assert!(!s.measured);
    }

    #[test]
    fn load_beats_copy_in_projection() {
        let n = node("skylakesp2").unwrap();
        let load = project_node_microbench(&n, MicrobenchKind::Load).value;
        let copy = project_node_microbench(&n, MicrobenchKind::Copy).value;
        assert!(load > copy);
    }
}
