//! machinestate stand-in: snapshot the hardware/software state of a node.
//!
//! The paper archives a `machinestate` dump with every benchmark job for
//! reproducibility (§4.3) and uploads it to Kadi4Mat. We snapshot the
//! simulated node's model plus the real host environment the simulation
//! ran on, as a JSON document.

use super::nodes::NodeModel;
use crate::util::json::Json;

/// Produce the machine-state document for `node` as used by job `job_name`.
pub fn machine_state(node: &NodeModel, job_name: &str, sim_time: f64) -> Json {
    let mut accels = Vec::new();
    for a in &node.accelerators {
        accels.push(
            Json::obj()
                .set("name", a.name)
                .set("mem_bw_gbs", a.mem_bw_gbs)
                .set("peak_fp32_gflops", a.peak_fp32_gflops),
        );
    }
    Json::obj()
        .set("tool", "machinestate-sim")
        .set("version", "0.4.1")
        .set("job", job_name)
        .set("sim_time", sim_time)
        .set(
            "hostname",
            node.host,
        )
        .set(
            "cpu",
            Json::obj()
                .set("model", node.cpu)
                .set("sockets", node.sockets)
                .set("cores_per_socket", node.cores_per_socket)
                .set("total_cores", node.cores())
                .set("frequency_ghz", node.freq_ghz)
                .set("frequency_governor", if node.testcluster { "pinned" } else { "turbo" })
                .set("flops_per_cycle_dp", node.flops_per_cycle),
        )
        .set(
            "memory",
            Json::obj().set("stream_bw_gbs", node.stream_bw_gbs),
        )
        .set("accelerators", Json::Arr(accels))
        .set(
            "host_environment",
            Json::obj()
                .set("os", std::env::consts::OS)
                .set("arch", std::env::consts::ARCH)
                .set("simulated", true),
        )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::nodes::node;

    #[test]
    fn snapshot_contains_node_facts() {
        let n = node("icx36").unwrap();
        let ms = machine_state(&n, "fe2ti216-icx36-mpi", 12.5);
        assert_eq!(ms.get("hostname").unwrap().as_str(), Some("icx36"));
        let cpu = ms.get("cpu").unwrap();
        assert_eq!(cpu.get("total_cores").unwrap().as_f64(), Some(72.0));
        assert_eq!(cpu.get("frequency_governor").unwrap().as_str(), Some("pinned"));
        // round-trips through JSON
        let parsed = Json::parse(&ms.to_string_pretty()).unwrap();
        assert_eq!(parsed, ms);
    }

    #[test]
    fn production_node_is_turbo() {
        let n = node("fritz").unwrap();
        let ms = machine_state(&n, "weakscale", 0.0);
        assert_eq!(
            ms.get("cpu").unwrap().get("frequency_governor").unwrap().as_str(),
            Some("turbo")
        );
    }
}
