//! Simulated NHR@FAU Testcluster (+ Fritz and JUWELS production nodes).
//!
//! The paper runs its CB pipeline on a heterogeneous single-node test
//! cluster (Tab. 2): every node is a different CPU/GPU architecture. That
//! hardware is not available here, so this module provides:
//!
//! * a **node catalogue** ([`catalogue`]) with per-node machine models
//!   (cores, pinned frequency, DP FLOP/cycle, STREAM-class memory
//!   bandwidth) calibrated from the public specs of the Tab. 2 hardware;
//! * an **execution model** ([`NodeModel::exec_time`]): a roofline-based
//!   time projection for a workload characterized by exact FLOP and
//!   traffic counts (counted, not sampled, by `perf::`);
//! * **microbenchmarks** ([`microbench`]) standing in for `likwid-bench`:
//!   stream/copy/load/peakflops really executed on the host, plus the
//!   catalogue projection used by the roofline dashboards;
//! * a **machine-state snapshot** ([`machinestate`]) standing in for the
//!   `machinestate` tool the paper archives for reproducibility.

pub mod machinestate;
pub mod microbench;
pub mod nodes;

pub use machinestate::machine_state;
pub use microbench::{run_host_microbench, MicrobenchKind, MicrobenchResult};
pub use nodes::{catalogue, Accelerator, NodeModel, Vendor};

/// A workload characterization: exact operation/traffic counts plus how
/// parallel the phase is. Produced by the instrumented applications.
#[derive(Debug, Clone, Copy, Default)]
pub struct WorkProfile {
    /// Double-precision floating point operations.
    pub flops: f64,
    /// Bytes moved to/from main memory.
    pub bytes: f64,
    /// Fraction of the work that parallelizes across cores (Amdahl).
    pub parallel_fraction: f64,
    /// Kernel efficiency relative to roofline (0..1]: how close this code
    /// gets to the machine limit (direct solvers ≈ high flop efficiency,
    /// sparse triangular solves ≈ low).
    pub efficiency: f64,
}

impl WorkProfile {
    pub fn new(flops: f64, bytes: f64) -> WorkProfile {
        WorkProfile {
            flops,
            bytes,
            parallel_fraction: 1.0,
            efficiency: 1.0,
        }
    }
    pub fn parallel(mut self, f: f64) -> Self {
        self.parallel_fraction = f;
        self
    }
    pub fn efficiency(mut self, e: f64) -> Self {
        self.efficiency = e;
        self
    }
    /// Operational intensity in FLOP/byte.
    pub fn intensity(&self) -> f64 {
        if self.bytes <= 0.0 {
            f64::INFINITY
        } else {
            self.flops / self.bytes
        }
    }
    pub fn add(&mut self, other: &WorkProfile) {
        self.flops += other.flops;
        self.bytes += other.bytes;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn work_profile_intensity() {
        let w = WorkProfile::new(100.0, 50.0);
        assert_eq!(w.intensity(), 2.0);
        assert!(WorkProfile::new(1.0, 0.0).intensity().is_infinite());
    }

    #[test]
    fn work_profile_accumulates() {
        let mut w = WorkProfile::new(1.0, 2.0);
        w.add(&WorkProfile::new(3.0, 4.0));
        assert_eq!(w.flops, 4.0);
        assert_eq!(w.bytes, 6.0);
    }
}
