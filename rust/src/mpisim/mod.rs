//! Simulated MPI / hybrid MPI+OpenMP execution geometry and communication
//! cost model.
//!
//! The paper's multi-node results (Figs. 11, 12, 14) run on Fritz and
//! JUWELS with pure-MPI and hybrid MPI/OpenMP parallelization. No
//! interconnect exists here, so communication is modelled with the
//! standard **alpha–beta (latency–bandwidth) model** plus the effects the
//! paper observes:
//!
//! * intra-node messages are much cheaper than inter-node ones,
//! * collectives over `p` ranks pay `O(log p)` latency terms — the reason
//!   pure-MPI macro solves degrade beyond ~16 nodes while hybrid (fewer,
//!   fatter ranks) wins (§5.1, Fig. 12),
//! * an optional **topology penalty** models non-optimal node allocations
//!   (the paper blames the 4→8-node communication jump in Fig. 14b on
//!   allocation topology),
//! * an **OpenMP runtime overhead** per parallel region models the paper's
//!   finding that the micro solves are slightly slower under hybrid
//!   parallelization (§5.1: "might be an overhead introduced by the OpenMP
//!   runtime", plus higher data volume in hybrid jobs).

/// Process geometry of a run.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Geometry {
    pub nodes: usize,
    pub ranks_per_node: usize,
    pub threads_per_rank: usize,
}

impl Geometry {
    /// Pure MPI: one rank per core.
    pub fn pure_mpi(nodes: usize, cores_per_node: usize) -> Geometry {
        Geometry {
            nodes,
            ranks_per_node: cores_per_node,
            threads_per_rank: 1,
        }
    }
    /// The paper's hybrid setup: 2 ranks per node (one per socket), the
    /// rest OpenMP threads.
    pub fn hybrid(nodes: usize, cores_per_node: usize) -> Geometry {
        Geometry {
            nodes,
            ranks_per_node: 2,
            threads_per_rank: cores_per_node / 2,
        }
    }
    pub fn total_ranks(&self) -> usize {
        self.nodes * self.ranks_per_node
    }
    pub fn cores_per_node(&self) -> usize {
        self.ranks_per_node * self.threads_per_rank
    }
    pub fn is_hybrid(&self) -> bool {
        self.threads_per_rank > 1
    }
}

/// Interconnect + runtime cost model.
#[derive(Debug, Clone)]
pub struct CommModel {
    /// Inter-node latency per message (s). InfiniBand-class: ~1.5 µs.
    pub alpha_inter: f64,
    /// Intra-node latency per message (s) (shared memory): ~0.3 µs.
    pub alpha_intra: f64,
    /// Inter-node inverse bandwidth (s/byte). 12.5 GB/s HDR-ish.
    pub beta_inter: f64,
    /// Intra-node inverse bandwidth (s/byte).
    pub beta_intra: f64,
    /// OpenMP parallel-region fork/join overhead per region (s).
    pub omp_region_overhead: f64,
    /// Extra data-volume factor observed for hybrid jobs (paper §5.1 "we
    /// see slightly higher data volume transferred during these hybrid
    /// jobs"). Multiplies message sizes under hybrid geometry.
    pub hybrid_volume_factor: f64,
    /// Topology penalty: multiplies inter-node beta when the allocation
    /// spans more than `topology_threshold_nodes` (non-adjacent switches).
    pub topology_penalty: f64,
    pub topology_threshold_nodes: usize,
}

impl Default for CommModel {
    fn default() -> CommModel {
        // Betas are *effective per-rank MPI message* rates, including
        // pack/unpack of strided ghost layers and on-node contention —
        // much lower than raw link/memcpy bandwidth, calibrated so the
        // single-node FSLBM phase shares land in the paper's Fig. 13
        // ranges (DESIGN.md §2).
        CommModel {
            alpha_inter: 1.5e-6,
            alpha_intra: 1.0e-6,
            beta_inter: 1.0 / 2.0e9,
            beta_intra: 1.0 / 3.0e9,
            omp_region_overhead: 4.0e-6,
            hybrid_volume_factor: 1.08,
            topology_penalty: 1.35,
            topology_threshold_nodes: 4,
        }
    }
}

impl CommModel {
    fn beta_inter_eff(&self, nodes: usize) -> f64 {
        if nodes > self.topology_threshold_nodes {
            self.beta_inter * self.topology_penalty
        } else {
            self.beta_inter
        }
    }

    /// Point-to-point message time.
    pub fn p2p(&self, bytes: f64, inter_node: bool, nodes: usize) -> f64 {
        if inter_node {
            self.alpha_inter + bytes * self.beta_inter_eff(nodes)
        } else {
            self.alpha_intra + bytes * self.beta_intra
        }
    }

    /// Allreduce over the geometry: recursive-doubling,
    /// `2·log2(p)` message steps of `bytes` each. Ranks on the same node
    /// use intra-node links for the first `log2(ranks_per_node)` steps.
    pub fn allreduce(&self, g: &Geometry, bytes: f64) -> f64 {
        let p = g.total_ranks().max(1);
        if p == 1 {
            return 0.0;
        }
        let bytes = self.volume(g, bytes);
        let steps = (p as f64).log2().ceil() as usize;
        let intra_steps = (g.ranks_per_node.max(1) as f64).log2().floor() as usize;
        let mut t = 0.0;
        for s in 0..steps {
            let inter = s >= intra_steps;
            t += 2.0 * self.p2p(bytes, inter, g.nodes);
        }
        t
    }

    /// Gather of `bytes` from every rank to a root (linearized tree).
    pub fn gather(&self, g: &Geometry, bytes_per_rank: f64) -> f64 {
        let p = g.total_ranks().max(1);
        if p == 1 {
            return 0.0;
        }
        let b = self.volume(g, bytes_per_rank);
        let steps = (p as f64).log2().ceil();
        // binomial tree: log p steps, message size grows toward root
        steps * self.alpha_inter + (p as f64 - 1.0) * b * self.beta_inter_eff(g.nodes)
    }

    /// Halo exchange: each rank exchanges `bytes` with `neighbors`
    /// neighbors; the fraction of neighbors that are off-node depends on
    /// the decomposition (supplied by the app).
    pub fn halo_exchange(
        &self,
        g: &Geometry,
        bytes_per_neighbor: f64,
        neighbors: usize,
        off_node_fraction: f64,
    ) -> f64 {
        let b = self.volume(g, bytes_per_neighbor);
        let off = off_node_fraction.clamp(0.0, 1.0);
        let n_off = neighbors as f64 * off;
        let n_on = neighbors as f64 - n_off;
        n_off * self.p2p(b, true, g.nodes) + n_on * self.p2p(b, false, g.nodes)
    }

    /// OpenMP fork/join cost for `regions` parallel regions.
    pub fn omp_overhead(&self, g: &Geometry, regions: usize) -> f64 {
        if g.is_hybrid() {
            regions as f64 * self.omp_region_overhead
        } else {
            0.0
        }
    }

    fn volume(&self, g: &Geometry, bytes: f64) -> f64 {
        if g.is_hybrid() {
            bytes * self.hybrid_volume_factor
        } else {
            bytes
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn geometry_helpers() {
        let g = Geometry::pure_mpi(4, 72);
        assert_eq!(g.total_ranks(), 288);
        assert!(!g.is_hybrid());
        let h = Geometry::hybrid(4, 72);
        assert_eq!(h.total_ranks(), 8);
        assert_eq!(h.threads_per_rank, 36);
        assert_eq!(h.cores_per_node(), 72);
        assert!(h.is_hybrid());
    }

    #[test]
    fn single_rank_collectives_free() {
        let m = CommModel::default();
        let g = Geometry { nodes: 1, ranks_per_node: 1, threads_per_rank: 1 };
        assert_eq!(m.allreduce(&g, 1e6), 0.0);
        assert_eq!(m.gather(&g, 1e6), 0.0);
    }

    #[test]
    fn allreduce_grows_with_ranks() {
        let m = CommModel::default();
        let t_small = m.allreduce(&Geometry::pure_mpi(2, 48), 8.0);
        let t_big = m.allreduce(&Geometry::pure_mpi(64, 48), 8.0);
        assert!(t_big > t_small);
    }

    #[test]
    fn hybrid_allreduce_cheaper_at_scale() {
        // the Fig. 12 mechanism: fewer ranks → fewer latency terms
        let m = CommModel::default();
        let nodes = 64;
        let t_mpi = m.allreduce(&Geometry::pure_mpi(nodes, 48), 64.0);
        let t_hyb = m.allreduce(&Geometry::hybrid(nodes, 48), 64.0);
        assert!(
            t_hyb < t_mpi,
            "hybrid {t_hyb} should beat pure-MPI {t_mpi} at {nodes} nodes"
        );
    }

    #[test]
    fn pure_mpi_cheaper_at_small_scale_for_micro() {
        // at 1 node the hybrid OpenMP overhead dominates (Fig. 11 micro solves)
        let m = CommModel::default();
        let g_h = Geometry::hybrid(1, 72);
        assert!(m.omp_overhead(&g_h, 1000) > 0.0);
        assert_eq!(m.omp_overhead(&Geometry::pure_mpi(1, 72), 1000), 0.0);
    }

    #[test]
    fn topology_penalty_kicks_in_beyond_threshold() {
        let m = CommModel::default();
        let t4 = m.p2p(1e6, true, 4);
        let t8 = m.p2p(1e6, true, 8);
        assert!(t8 > t4 * 1.2, "t8={t8} t4={t4}");
    }

    #[test]
    fn halo_off_node_fraction_matters() {
        let m = CommModel::default();
        let g = Geometry::pure_mpi(8, 48);
        let all_on = m.halo_exchange(&g, 1e5, 4, 0.0);
        let all_off = m.halo_exchange(&g, 1e5, 4, 1.0);
        assert!(all_off > all_on);
    }

    #[test]
    fn hybrid_moves_more_volume() {
        let m = CommModel::default();
        let g_m = Geometry::pure_mpi(2, 48);
        let g_h = Geometry::hybrid(2, 48);
        // same message, hybrid pays the volume factor (paper's observation)
        let b = 1e6;
        let t_m = m.halo_exchange(&g_m, b, 1, 1.0);
        let t_h = m.halo_exchange(&g_h, b, 1, 1.0);
        assert!(t_h > t_m);
    }
}
