//! # par — deterministic fan-out across scoped worker threads
//!
//! The whole collect → parse → upload → detect hot path used to run on
//! one thread; this module is the minimal parallel substrate that fixes
//! that **without giving up the replay contract**. Everything in cbench
//! that claims byte-identical output (timelines, TSDB contents, alert
//! books, traces) keeps that claim for any thread count because every
//! fan-out goes through [`map`], whose result order is the *input*
//! order — worker scheduling decides only the wall-clock, never the
//! merge order.
//!
//! Design (deliberately boring — no new dependencies, std only):
//!
//! * **No work stealing.** Workers are plain [`std::thread::scope`]
//!   threads pulling `(index, item)` pairs from one shared queue (a
//!   mutexed iterator — the spmc channel std does not ship; sharing an
//!   `mpsc::Receiver` across workers needs the same mutex anyway).
//!   Results land in per-index slots, so the output `Vec` is assembled
//!   in input order no matter which worker finished when.
//! * **Global thread count**, set once from the CLI (`--threads N`,
//!   default [`std::thread::available_parallelism`]): the pool is a
//!   process-wide policy like `obs::metrics::set_enabled`, not a value
//!   threaded through every call site. `1` (or one-element inputs) runs
//!   inline on the caller's thread — zero spawns, zero locks.
//! * **No nested fan-out.** A worker that reaches another [`map`] (e.g.
//!   a parallel shard prefetch whose materialization parses line
//!   protocol in parallel) runs it inline: parallelism stays bounded by
//!   the configured thread count instead of multiplying per layer.
//!
//! What must stay serial stays serial at the call sites: per-pipeline
//! collect order (`(completion, pid)`), `Db::insert` ordering within a
//! shard, alert-book ingestion, and the manifest rename that commits a
//! save. See ARCHITECTURE.md §7 for the full concurrency model.

use std::cell::Cell;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Configured worker count; `0` means "not set — use
/// [`std::thread::available_parallelism`]".
static THREADS: AtomicUsize = AtomicUsize::new(0);

thread_local! {
    /// Set inside pool workers so nested [`map`] calls run inline.
    static IN_WORKER: Cell<bool> = Cell::new(false);
}

/// Set the process-wide worker count. `0` restores the default
/// (one worker per available core). Safe to call at any time; fan-outs
/// already in flight keep the count they started with.
pub fn set_threads(n: usize) {
    THREADS.store(n, Ordering::Relaxed);
}

/// The effective worker count for the next fan-out.
pub fn threads() -> usize {
    match THREADS.load(Ordering::Relaxed) {
        0 => std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1),
        n => n,
    }
}

/// True when the current thread is a pool worker (nested fan-outs run
/// inline — see the module docs).
pub fn in_worker() -> bool {
    IN_WORKER.with(|w| w.get())
}

/// Apply `f` to every item, fanning the work across up to [`threads`]
/// scoped workers, and return the results **in input order** — the
/// output is identical to `items.into_iter().map(f).collect()` for any
/// thread count (determinism by ordered merge, not by scheduling).
/// Runs inline when one worker suffices or when called from inside a
/// worker. A panicking `f` propagates to the caller after the scope
/// joins, as with serial iteration.
pub fn map<T, R, F>(items: Vec<T>, f: F) -> Vec<R>
where
    T: Send,
    R: Send,
    F: Fn(T) -> R + Sync,
{
    let workers = threads().min(items.len());
    if workers <= 1 || in_worker() {
        return items.into_iter().map(f).collect();
    }
    let len = items.len();
    // the work queue: workers pull (index, item) pairs; per-index result
    // slots make the merge order the input order
    let queue = Mutex::new(items.into_iter().enumerate());
    let slots: Vec<Mutex<Option<R>>> = (0..len).map(|_| Mutex::new(None)).collect();
    std::thread::scope(|s| {
        for _ in 0..workers {
            s.spawn(|| {
                IN_WORKER.with(|w| w.set(true));
                loop {
                    // hold the queue lock only to pull the next item —
                    // `f` runs unlocked
                    let next = queue.lock().expect("queue poisoned").next();
                    let Some((i, item)) = next else { break };
                    let r = f(item);
                    *slots[i].lock().expect("slot poisoned") = Some(r);
                }
            });
        }
    });
    slots
        .into_iter()
        .map(|m| {
            m.into_inner()
                .expect("slot poisoned")
                .expect("every queue item fills its slot")
        })
        .collect()
}

/// [`map`] for fallible work: returns the first `Err` **in input
/// order** (not completion order — the same error a serial loop would
/// surface), or all results in input order.
pub fn try_map<T, R, E, F>(items: Vec<T>, f: F) -> Result<Vec<R>, E>
where
    T: Send,
    R: Send,
    E: Send,
    F: Fn(T) -> Result<R, E> + Sync,
{
    map(items, f).into_iter().collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    /// `THREADS` is process-global and the harness runs tests in
    /// parallel — tests that assert on it serialize through this lock.
    /// (Poisoning is fine: a poisoned lock means another test failed.)
    static TEST_LOCK: Mutex<()> = Mutex::new(());

    #[test]
    fn map_preserves_input_order_for_any_thread_count() {
        let _g = TEST_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        let input: Vec<usize> = (0..1000).collect();
        for t in [1usize, 2, 3, 4, 8, 16] {
            set_threads(t);
            let out = map(input.clone(), |x| x * 2);
            assert_eq!(out, input.iter().map(|x| x * 2).collect::<Vec<_>>(), "t={t}");
        }
        set_threads(0);
    }

    #[test]
    fn map_handles_degenerate_inputs() {
        let _g = TEST_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        set_threads(4);
        assert_eq!(map(Vec::<usize>::new(), |x| x), Vec::<usize>::new());
        assert_eq!(map(vec![7usize], |x| x + 1), vec![8]);
        set_threads(0);
    }

    #[test]
    fn map_actually_runs_on_worker_threads() {
        let _g = TEST_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        set_threads(4);
        let main_id = std::thread::current().id();
        let offloaded = AtomicUsize::new(0);
        let _ = map((0..64).collect::<Vec<usize>>(), |x| {
            if std::thread::current().id() != main_id {
                offloaded.fetch_add(1, Ordering::Relaxed);
            }
            x
        });
        assert_eq!(offloaded.load(Ordering::Relaxed), 64, "workers do all the pulling");
        set_threads(0);
    }

    #[test]
    fn nested_map_runs_inline_and_stays_correct() {
        let _g = TEST_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        set_threads(4);
        let out = map((0..8).collect::<Vec<usize>>(), |x| {
            assert!(in_worker());
            // the inner fan-out must not spawn (and must still be right)
            map((0..4).collect::<Vec<usize>>(), |y| x * 10 + y)
        });
        for (x, inner) in out.iter().enumerate() {
            assert_eq!(inner, &vec![x * 10, x * 10 + 1, x * 10 + 2, x * 10 + 3]);
        }
        set_threads(0);
    }

    #[test]
    fn try_map_returns_the_first_error_in_input_order() {
        let _g = TEST_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        for t in [1usize, 4] {
            set_threads(t);
            let r: Result<Vec<usize>, String> = try_map((0..100).collect(), |x| {
                if x == 13 || x == 77 {
                    Err(format!("bad {x}"))
                } else {
                    Ok(x)
                }
            });
            assert_eq!(r.unwrap_err(), "bad 13", "t={t}: lowest index wins");
            let ok: Result<Vec<usize>, String> = try_map((0..10).collect(), Ok);
            assert_eq!(ok.unwrap(), (0..10).collect::<Vec<_>>());
        }
        set_threads(0);
    }

    #[test]
    fn threads_zero_means_available_parallelism() {
        let _g = TEST_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        set_threads(0);
        assert!(threads() >= 1);
        set_threads(3);
        assert_eq!(threads(), 3);
        set_threads(0);
    }
}
