//! Service-layer benchmark: an in-process serve:: instance driven by the
//! loadgen client over real TCP — sustained ingest/query QPS and request
//! latency percentiles, plus the end-to-end proof that a regression
//! injected through the HTTP API opens an alert readable back through
//! the HTTP API.
//!
//! `cargo bench --bench bench_serve`; CI embeds SERVE_JSON into the
//! per-commit bench report next to CAMPAIGN_JSON / INGEST_JSON.

use cbench::serve::loadgen::{run, LoadgenConfig};
use cbench::serve::{start, ServeConfig};

fn main() {
    println!("== bench_serve ==\n");

    let handle = start(ServeConfig {
        addr: "127.0.0.1:0".to_string(), // ephemeral port
        ..ServeConfig::default()
    })
    .expect("server starts");
    let addr = handle.addr.to_string();
    println!("in-process server on {addr} ({} workers)", handle.threads());

    // throughput phase: concurrent clients, disjoint projects, healthy
    // data plus injected single-point regressions at the tail
    let report = run(&LoadgenConfig {
        addr: addr.clone(),
        project: "bench".to_string(),
        clients: 4,
        batches: 25,
        batch_points: 40,
        queries: 100,
        inject_regression: true,
    });
    assert_eq!(report.http_errors, 0, "bench traffic must be error-free");
    assert!(
        report.alerts_open >= 1,
        "the injected drop must open an alert visible over HTTP"
    );
    println!(
        "ingest: {} requests ({} points) at {:.0} req/s",
        report.ingest_requests, report.points_sent, report.ingest_qps
    );
    println!(
        "query : {} requests at {:.0} req/s",
        report.query_requests, report.query_qps
    );
    println!(
        "latency: p50 {:.3} ms, p99 {:.3} ms; {} open alerts read back",
        report.p50_ms, report.p99_ms, report.alerts_open
    );

    let shutdown = handle.stop();
    println!(
        "drain: {} requests served, {} errors",
        shutdown.requests, shutdown.errors
    );

    println!(
        "SERVE_JSON {{\"ingest_qps\":{:.2},\"query_qps\":{:.2},\"p50_ms\":{:.3},\"p99_ms\":{:.3},\"points_sent\":{},\"alerts_open\":{},\"requests\":{},\"http_errors\":{}}}",
        report.ingest_qps,
        report.query_qps,
        report.p50_ms,
        report.p99_ms,
        report.points_sent,
        report.alerts_open,
        shutdown.requests,
        report.http_errors
    );
}
