//! End-to-end pipeline benchmarks: one per paper benchmark case (Tab. 3),
//! measuring the full coordinator path (matrix → scheduler → parse →
//! TSDB → records) plus per-figure generator latency.
//!
//! `cargo bench --bench bench_pipeline`

use cbench::coordinator::{
    fe2ti_pipeline::fe2ti_job_matrix, walberla_pipeline::walberla_job_matrix, BenchConfig,
    CbSystem,
};
use cbench::util::stats::Bench;
use cbench::vcs::Repository;

fn main() {
    println!("== bench_pipeline: coordinator end-to-end ==\n");

    // fe2ti216/fe2ti1728 full 100-job pipeline
    let mut b = Bench::quick("fe2ti_pipeline_100_jobs");
    b.budget_secs = 30.0;
    b.max_iters = 5;
    let r = b.run(|| {
        let mut repo = Repository::new("fe2ti");
        let ev = repo.commit_change("master", "a", "c", 0.0, "benchmark.cfg", "");
        let mut cb = CbSystem::new();
        let jobs = fe2ti_job_matrix(&BenchConfig::default(), 5, 1);
        cb.execute_pipeline(&ev, false, jobs, "fe2ti").unwrap().jobs_total
    });
    println!("{}", r.report_throughput(100.0, "job"));

    // walberla 48-job pipeline (UniformGridCPU × 11 nodes + FSLBM × 4)
    let mut b = Bench::quick("walberla_pipeline_48_jobs");
    b.budget_secs = 10.0;
    let r = b.run(|| {
        let mut repo = Repository::new("walberla");
        let ev = repo.commit_change("master", "a", "c", 0.0, "benchmark.cfg", "");
        let mut cb = CbSystem::new();
        let jobs = walberla_job_matrix(&BenchConfig::default());
        cb.execute_pipeline(&ev, true, jobs, "lbm").unwrap().jobs_total
    });
    println!("{}", r.report_throughput(48.0, "job"));

    // per-figure generator latency (each regenerates a paper artifact)
    println!("\n== report generators ==\n");
    for id in ["tab2", "fig8", "fig13", "fig14"] {
        let mut b = Bench::quick(&format!("report_{id}"));
        b.budget_secs = 5.0;
        let r = b.run(|| cbench::report::run_report(id, None).unwrap().len());
        println!("{}", r.report());
    }
    // the heavy ones, once each
    for id in ["fig9", "fig11", "fig12"] {
        let t = std::time::Instant::now();
        let len = cbench::report::run_report(id, None).unwrap().len();
        println!(
            "{:<40} single run: {} ({} chars)",
            format!("report_{id}"),
            cbench::util::fmt_secs(t.elapsed().as_secs_f64()),
            len
        );
    }
}
