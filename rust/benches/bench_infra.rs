//! Infrastructure benchmarks: the CB substrate hot paths — TSDB ingest +
//! query, scheduler throughput, datastore, JSON, FSLBM step.
//!
//! `cargo bench --bench bench_infra`

use cbench::apps::walberla::collision::CollisionOp;
use cbench::apps::walberla::fslbm::FsBlock;
use cbench::cluster::nodes::catalogue;
use cbench::datastore::DataStore;
use cbench::slurm::{JobOutcome, JobSpec, Scheduler};
use cbench::tsdb::{Aggregate, Db, Point, Query};
use cbench::util::json::Json;
use cbench::util::stats::Bench;

fn main() {
    println!("== bench_infra ==\n");

    // TSDB ingest
    let mk_point = |i: i64| {
        Point::new("lbm", i)
            .tag("node", if i % 2 == 0 { "icx36" } else { "rome1" })
            .tag("collision_op", ["srt", "trt", "mrt", "cumulant"][(i % 4) as usize])
            .field("mlups", 1000.0 + i as f64)
            .field("runtime", 1.0 / (1.0 + i as f64))
    };
    let mut b = Bench::new("tsdb_insert_1k");
    let r = b.run(|| {
        let mut db = Db::new();
        for i in 0..1000 {
            db.insert(mk_point(i));
        }
        db
    });
    println!("{}", r.report_throughput(1000.0, "point"));

    // line-protocol encode+parse roundtrip
    let p = mk_point(42);
    let mut b = Bench::new("line_protocol_roundtrip");
    let r = b.run(|| Point::parse_line(&p.to_line()).unwrap());
    println!("{}", r.report());

    // query with grouping over 10k points
    let mut db = Db::new();
    for i in 0..10_000 {
        db.insert(mk_point(i));
    }
    let mut b = Bench::new("tsdb_query_group_10k");
    let r = b.run(|| {
        Query::new("lbm", "mlups")
            .group_by(&["node", "collision_op"])
            .run_agg(&db, Aggregate::Last)
    });
    println!("{}", r.report_throughput(10_000.0, "point"));

    // scheduler: 200 trivial jobs over the 11-node cluster
    let mut b = Bench::new("slurm_200_jobs");
    b.budget_secs = 1.5;
    let r = b.run(|| {
        let mut s = Scheduler::new(catalogue().into_iter().filter(|n| n.testcluster).collect());
        let hosts: Vec<String> = s.nodes().map(|n| n.host.to_string()).collect();
        for i in 0..200 {
            s.sbatch(
                JobSpec {
                    name: format!("j{i}"),
                    nodelist: hosts[i % hosts.len()].clone(),
                    timelimit_min: 10.0,
                },
                Box::new(|_n, _t| JobOutcome {
                    duration: 1.0,
                    stdout: String::new(),
                    exit_code: 0,
                }),
            )
            .unwrap();
        }
        s.wait_all().len()
    });
    println!("{}", r.report_throughput(200.0, "job"));

    // datastore: 300 records + links (one pipeline's worth)
    let mut b = Bench::new("datastore_300_records");
    let r = b.run(|| {
        let mut ds = DataStore::new();
        let coll = ds.create_collection("p", "pipeline");
        let mut prev = None;
        for i in 0..300 {
            let id = ds.create_record(&format!("r{i}"), "rec", "job-log").unwrap();
            ds.add_to_collection(coll, id).unwrap();
            if let Some(p) = prev {
                ds.link(id, p, "belongs to").unwrap();
            }
            prev = Some(id);
        }
        ds.n_records()
    });
    println!("{}", r.report_throughput(300.0, "record"));

    // JSON parse of a machinestate-sized doc
    let node = catalogue().into_iter().next().unwrap();
    let ms = cbench::cluster::machinestate::machine_state(&node, "bench", 0.0).to_string_pretty();
    let mut b = Bench::new("json_parse_machinestate");
    let r = b.run(|| Json::parse(&ms).unwrap());
    println!("{}", r.report_throughput(ms.len() as f64, "byte"));

    // FSLBM full step (the Fig. 13 compute phase, real physics)
    let mut blk = FsBlock::new(16, 16, 8);
    blk.init_gravity_wave(0.1);
    let mut b = Bench::new("fslbm_step_16x16x8");
    b.budget_secs = 1.5;
    let r = b.run(|| blk.step(CollisionOp::Srt));
    println!("{}", r.report_throughput((16 * 16 * 8) as f64, "cell"));
}
