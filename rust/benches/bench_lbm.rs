//! LBM kernel benchmarks (Fig. 6/8 workloads): native rust sweep per
//! collision operator + stencil, host stream roofline comparison, and the
//! PJRT-artifact kernel.
//!
//! `cargo bench --bench bench_lbm`

use cbench::apps::walberla::collision::CollisionOp;
use cbench::apps::walberla::grid::Block;
use cbench::apps::walberla::lattice::{d3q19, d3q27};
use cbench::cluster::microbench::{run_host_microbench, MicrobenchKind};
use cbench::util::stats::Bench;

fn main() {
    println!("== bench_lbm: uniform-grid sweeps (one sweep = collide+ghost+stream) ==\n");

    // host roofline context: what would a pure-bandwidth LBM bound be here?
    let stream = run_host_microbench(MicrobenchKind::Stream, 1 << 22, 3);
    let pmax_d3q19 = stream.value * 1e9 / 304.0 / 1e6;
    println!(
        "host stream: {:.2} GB/s  ->  P_max(D3Q19,f64) = {:.1} MLUP/s\n",
        stream.value, pmax_d3q19
    );

    let n = 24usize;
    let cells = (n * n * n) as f64;
    for (lat, lname) in [(d3q19(), "d3q19"), (d3q27(), "d3q27")] {
        for op in CollisionOp::all() {
            let mut block = Block::new(lat.clone(), n, n, n);
            block.init_equilibrium(1.0, [0.02, 0.01, 0.0]);
            let mut b = Bench::new(&format!("lbm_{}_{}_{}", lname, op.name(), n));
            b.budget_secs = 1.0;
            let r = b.run(|| block.step(op, 0.6));
            println!("{}", r.report_throughput(cells, "cell"));
            let mlups = cells / r.secs_per_iter.p50 / 1e6;
            println!(
                "{:<40}   {:>8.2} MLUP/s  ({:.1}% of host stream P_max)",
                "",
                mlups,
                100.0 * mlups / pmax_d3q19
            );
        }
    }

    // the AOT Pallas kernel through PJRT (build artifacts first)
    println!("\n== PJRT artifact kernel ==\n");
    match cbench::runtime::Engine::open("artifacts") {
        Ok(mut engine) => {
            // pallas-lowered vs jnp-lowered vs 4-step-fused (§Perf L2)
            for name in [
                "lbm_d3q19_srt_16",
                "lbm_d3q19_trt_16",
                "lbm_d3q19_srt_ref_16",
                "lbm_d3q19_srt_x4_16",
            ] {
                let meta = engine.meta(name).cloned();
                let Some(meta) = meta else { continue };
                let len: usize = meta.shape.iter().product();
                let f = vec![1.0f32 / 19.0; len];
                engine.load(name).unwrap();
                let mut b = Bench::quick(&format!("pjrt_{name}"));
                b.budget_secs = 2.0;
                let cells: f64 = meta.shape[1..].iter().product::<usize>() as f64;
                let r = b.run(|| engine.lbm_step(name, &f).unwrap());
                println!("{}", r.report_throughput(cells, "cell"));
            }
        }
        Err(e) => println!("(skipping: {e})"),
    }
}
