//! Sparse-solver benchmarks (Fig. 9/10 workloads): the four FE2TI solver
//! packages on the real nonlinear RVE problem, plus raw kernel benches.
//!
//! `cargo bench --bench bench_solvers`

use cbench::apps::fe2ti::rve::{Material, Rve};
use cbench::apps::fe2ti::solvers::{Compiler, SolverConfig, SolverKind};
use cbench::sparse::{cg, gmres, testmat::laplacian2d, Csr, Ilu0, SparseLu, Work};
use cbench::util::stats::Bench;

fn main() {
    println!("== bench_solvers: full nonlinear RVE solves (n=8, 512 dof) ==\n");
    for kind in SolverKind::paper_set() {
        let cfg = SolverConfig::new(kind, Compiler::Intel);
        let mut b = Bench::new(&format!("rve_solve_{}", kind.name()));
        b.budget_secs = 1.5;
        let r = b.run(|| {
            let mut rve = Rve::new(8, Material::default());
            rve.solve(0.125, &cfg, 1e-7)
        });
        println!("{}", r.report());
        // counted work of one solve (exact)
        let mut rve = Rve::new(8, Material::default());
        let stats = rve.solve(0.125, &cfg, 1e-7);
        println!(
            "{:<40}   counted: {:.3e} FLOP, {:.3e} B, {} newton / {} inner iters",
            "", stats.work.flops, stats.work.bytes, stats.newton_iters, stats.inner_iters
        );
    }

    println!("\n== raw kernels on the 2-D Laplacian (m=40, 1600 dof) ==\n");
    let a: Csr = laplacian2d(40);
    let rhs = vec![1.0; a.n];

    let mut b = Bench::new("sparse_lu_factor");
    let r = b.run(|| SparseLu::factor(&a).unwrap());
    println!("{}", r.report());

    let lu = SparseLu::factor(&a).unwrap();
    let mut b = Bench::new("sparse_lu_solve");
    let r = b.run(|| {
        let mut w = Work::default();
        lu.solve(&rhs, &mut w)
    });
    println!("{}", r.report());

    let mut b = Bench::new("ilu0_factor");
    let r = b.run(|| Ilu0::factor(&a).unwrap());
    println!("{}", r.report());

    let ilu = Ilu0::factor(&a).unwrap();
    let mut b = Bench::new("gmres_ilu_1e-8");
    let r = b.run(|| gmres(&a, &rhs, Some(&ilu), 1e-8, 40, 2000));
    println!("{}", r.report());

    let mut b = Bench::new("gmres_ilu_1e-4");
    let r = b.run(|| gmres(&a, &rhs, Some(&ilu), 1e-4, 40, 2000));
    println!("{}", r.report());

    let mut b = Bench::new("cg_1e-8");
    let r = b.run(|| cg(&a, &rhs, 1e-8, 2000));
    println!("{}", r.report());

    let mut y = vec![0.0; a.n];
    let mut b = Bench::new("spmv");
    let r = b.run(|| {
        let mut w = Work::default();
        a.matvec(&rhs, &mut y, &mut w);
    });
    println!("{}", r.report_throughput(2.0 * a.nnz() as f64, "flop"));
}
