//! Regression-detection benchmarks: the detector runs inside
//! `coordinator::execute_pipeline` after every upload, so it must stay
//! off the pipeline's hot-path budget even on a production-sized TSDB.
//!
//! `cargo bench --bench bench_regress`

use cbench::regress::{cusum_changepoint, mann_whitney, welch_t, Detector, Policy};
use cbench::regress::detector::Direction;
use cbench::tsdb::{Db, Point};
use cbench::util::rng::Rng;
use cbench::util::stats::Bench;

/// Synthetic production-shaped TSDB: `series` series × `per_series`
/// pipeline executions, ~8% of series carrying a planted 15% drop.
/// Every live series reports at every pipeline trigger timestamp — the
/// shape `coordinator::collect_pipeline` uploads, and the one the
/// detector's `tail(n)` pushdown is bounded against.
fn synthetic_db(series: usize, per_series: usize, seed: u64) -> Db {
    let mut rng = Rng::new(seed);
    let mut db = Db::new();
    let ops = ["srt", "trt", "mrt", "cumulant"];
    // per-series personalities first ...
    let params: Vec<(String, &str, f64, bool, usize)> = (0..series)
        .map(|s| {
            (
                format!("node{:02}", s / ops.len()),
                ops[s % ops.len()],
                400.0 + 50.0 * (s % 17) as f64,
                rng.uniform() < 0.08,
                per_series / 2 + rng.below(per_series / 3),
            )
        })
        .collect();
    // ... then one upload wave per trigger, in time order (the appends hit
    // the TSDB's fast path, like real pipeline uploads do)
    for t in 0..per_series {
        for (s, (node, op, base, planted, cp)) in params.iter().enumerate() {
            let level = if *planted && t >= *cp { base * 0.85 } else { *base };
            db.insert(
                Point::new("lbm", t as i64 * 1_000_000_000)
                    .tag("case", "uniformgridcpu")
                    .tag("node", node)
                    .tag("collision_op", op)
                    .tag("commit", &format!("c{s:03}x{t:04}"))
                    .field("mlups", level * rng.jitter(0.01)),
            );
        }
    }
    db
}

fn main() {
    println!("== bench_regress ==\n");

    // full detector sweep over a 10k-point TSDB (500 series x 20 runs)
    let db = synthetic_db(500, 20, 42);
    assert_eq!(db.len(), 10_000);
    let det = Detector::new().policy(
        Policy::new("lbm-mlups", "lbm", "mlups")
            .group_by(&["case", "node", "collision_op"])
            .direction(Direction::HigherIsBetter)
            .thresholds(0.08, 0.05, 0.5),
    );
    let mut found = 0usize;
    let mut b = Bench::new("detector_10k_points_500_series");
    let r = b.run(|| {
        let f = det.detect(&db);
        found = f.len();
        f.len()
    });
    println!("{}   ({found} findings)", r.report_throughput(10_000.0, "point"));

    // deep-history variant: few series, long windows
    let db_deep = synthetic_db(20, 500, 7);
    let mut b = Bench::new("detector_10k_points_20_series");
    let r = b.run(|| det.detect(&db_deep).len());
    println!("{}", r.report_throughput(10_000.0, "point"));

    // tail(n) pushdown: the per-pipeline check must not grow with history
    // length. Same series count, deepening history — since the detector
    // queries `.tail(baseline+recent)` the cost per detect() stays flat
    // instead of scaling with the full series (pre-pushdown behaviour).
    println!("\n== detector cost vs history depth (tail pushdown) ==\n");
    for per_series in [20usize, 200, 1000] {
        let db = synthetic_db(100, per_series, 11);
        let mut b = Bench::new(&format!("detect_100_series_x{per_series}_history"));
        b.budget_secs = 2.0;
        let r = b.run(|| det.detect(&db).len());
        println!("{}   ({} points total)", r.report(), db.len());
    }

    // statistical primitives on window-sized samples
    let mut rng = Rng::new(1);
    let a: Vec<f64> = (0..100).map(|_| rng.gauss(1000.0, 10.0)).collect();
    let c: Vec<f64> = (0..100).map(|_| rng.gauss(950.0, 10.0)).collect();
    let mut b = Bench::new("welch_t_100v100");
    let r = b.run(|| welch_t(&a, &c).unwrap().p);
    println!("{}", r.report());

    let mut b = Bench::new("mann_whitney_100v100");
    let r = b.run(|| mann_whitney(&a, &c).unwrap().p);
    println!("{}", r.report());

    let long: Vec<f64> = (0..1000)
        .map(|i| {
            if i < 600 {
                rng.gauss(100.0, 2.0)
            } else {
                rng.gauss(90.0, 2.0)
            }
        })
        .collect();
    let mut b = Bench::new("cusum_changepoint_1k");
    let r = b.run(|| cusum_changepoint(&long).index);
    println!("{}", r.report_throughput(1000.0, "point"));
}
