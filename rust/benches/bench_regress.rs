//! Regression-detection benchmarks: the detector runs inside
//! `coordinator::execute_pipeline` after every upload, so it must stay
//! off the pipeline's hot-path budget even on a production-sized TSDB.
//!
//! `cargo bench --bench bench_regress`

use cbench::obs::metrics as om;
use cbench::regress::{cusum_changepoint, mann_whitney, welch_t, Detector, DetectorState, Policy};
use cbench::regress::detector::Direction;
use cbench::tsdb::{Db, Point, Query};
use cbench::util::rng::Rng;
use cbench::util::stats::Bench;
use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

/// Counting allocator for the MEMORY_JSON section: a thin System wrapper
/// whose relaxed counter costs nothing measurable on the other benches.
struct CountingAlloc;

static ALLOCS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }
    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

/// Synthetic production-shaped TSDB: `series` series × `per_series`
/// pipeline executions, ~8% of series carrying a planted 15% drop.
/// Every live series reports at every pipeline trigger timestamp — the
/// shape `coordinator::collect_pipeline` uploads, and the one the
/// detector's `tail(n)` pushdown is bounded against.
fn synthetic_db(series: usize, per_series: usize, seed: u64) -> Db {
    synthetic_db_span(series, per_series, seed, cbench::tsdb::DEFAULT_SHARD_SPAN_NS)
}

/// [`synthetic_db`] with an explicit shard span — the persistence benches
/// use small shards so the lazy cold load has real shard granularity.
fn synthetic_db_span(series: usize, per_series: usize, seed: u64, span_ns: i64) -> Db {
    let mut rng = Rng::new(seed);
    let mut db = Db::with_shard_span(span_ns);
    let ops = ["srt", "trt", "mrt", "cumulant"];
    // per-series personalities first ...
    let params: Vec<(String, &str, f64, bool, usize)> = (0..series)
        .map(|s| {
            (
                format!("node{:02}", s / ops.len()),
                ops[s % ops.len()],
                400.0 + 50.0 * (s % 17) as f64,
                rng.uniform() < 0.08,
                per_series / 2 + rng.below(per_series / 3),
            )
        })
        .collect();
    // ... then one upload wave per trigger, in time order (the appends hit
    // the TSDB's fast path, like real pipeline uploads do)
    for t in 0..per_series {
        for (s, (node, op, base, planted, cp)) in params.iter().enumerate() {
            let level = if *planted && t >= *cp { base * 0.85 } else { *base };
            db.insert(
                Point::new("lbm", t as i64 * 1_000_000_000)
                    .tag("case", "uniformgridcpu")
                    .tag("node", node)
                    .tag("collision_op", op)
                    .tag("commit", &format!("c{s:03}x{t:04}"))
                    .field("mlups", level * rng.jitter(0.01)),
            );
        }
    }
    db
}

fn main() {
    println!("== bench_regress ==\n");

    // full detector sweep over a 10k-point TSDB (500 series x 20 runs)
    let db = synthetic_db(500, 20, 42);
    assert_eq!(db.len(), 10_000);
    let det = Detector::new().policy(
        Policy::new("lbm-mlups", "lbm", "mlups")
            .group_by(&["case", "node", "collision_op"])
            .direction(Direction::HigherIsBetter)
            .thresholds(0.08, 0.05, 0.5),
    );
    let mut found = 0usize;
    let mut b = Bench::new("detector_10k_points_500_series");
    let r = b.run(|| {
        let f = det.detect(&db);
        found = f.len();
        f.len()
    });
    println!("{}   ({found} findings)", r.report_throughput(10_000.0, "point"));

    // deep-history variant: few series, long windows
    let db_deep = synthetic_db(20, 500, 7);
    let mut b = Bench::new("detector_10k_points_20_series");
    let r = b.run(|| det.detect(&db_deep).len());
    println!("{}", r.report_throughput(10_000.0, "point"));

    // tail(n) pushdown over the sharded store: the per-pipeline check
    // must not grow with history length. Same series count, history
    // deepening 10× and 100× — the detector queries
    // `.tail(baseline+recent)`, whose reverse walk streams newest-shard-
    // first, so the cost per detect() stays flat instead of scaling with
    // the full series. DEEPHIST_JSON records the 10× ratio (CI embeds it
    // into the per-commit bench history; the acceptance gate is ±10%).
    println!("\n== detector cost vs history depth (shards + tail pushdown) ==\n");
    let mut times_ms: Vec<(usize, f64)> = Vec::new();
    for per_series in [100usize, 1000, 10_000] {
        let db = synthetic_db(100, per_series, 11);
        let mut b = Bench::new(&format!("detect_100_series_x{per_series}_history"));
        b.budget_secs = 2.0;
        let r = b.run(|| det.detect(&db).len());
        println!(
            "{}   ({} points, {} shards)",
            r.report(),
            db.len(),
            db.shards("lbm").len()
        );
        times_ms.push((per_series, r.secs_per_iter.p50 * 1e3));
    }
    let t_1x = times_ms[0].1;
    let t_10x = times_ms[1].1;
    let ratio = if t_1x > 0.0 { t_10x / t_1x } else { 1.0 };
    println!(
        "DEEPHIST_JSON {{\"t_1x_ms\":{t_1x:.4},\"t_10x_ms\":{t_10x:.4},\"t_100x_ms\":{:.4},\"ratio_10x\":{ratio:.4},\"flat_within_10pct\":{}}}",
        times_ms[2].1,
        ratio <= 1.10
    );

    // compaction: a multi-year history rolled up to per-series shard
    // summaries — full-history dashboard scans shrink with the point
    // count while the detector's trailing windows stay raw
    println!("\n== compaction on deep history ==\n");
    let mut db_old = synthetic_db(100, 10_000, 13);
    let full_scan = |db: &Db| {
        Query::new("lbm", "mlups")
            .group_by(&["node", "collision_op"])
            .run(db)
            .len()
    };
    let mut b = Bench::new("full_scan_1M_points_raw");
    b.budget_secs = 2.0;
    let r_raw = b.run(|| full_scan(&db_old));
    println!("{}", r_raw.report());
    let detect_raw = det.detect(&db_old).len();
    // retain the trailing ~64 pipeline triggers raw, roll up the rest
    let rep = db_old.compact(64 * 1_000_000_000);
    println!(
        "compacted {} of {} shards: {} -> {} points",
        rep.shards_compacted, rep.shards_seen, rep.points_before, rep.points_after
    );
    let mut b = Bench::new("full_scan_1M_points_compacted");
    b.budget_secs = 2.0;
    let r_cmp = b.run(|| full_scan(&db_old));
    println!("{}", r_cmp.report());
    assert_eq!(
        det.detect(&db_old).len(),
        detect_raw,
        "detector windows live in the retained raw range — findings unchanged"
    );

    // cold-load persistence: the manifest layout parses its shard index
    // eagerly and shard bodies lazily, so "restart + first detect" reads
    // only the newest shard(s) — flat as the on-disk history deepens
    // 1× → 100×. The legacy single-file load pays the whole history
    // (eager contrast figure). PERSIST_JSON is embedded into the
    // per-commit bench JSON by CI; the acceptance gate is ±10%.
    println!("\n== cold load: manifest (lazy) vs legacy single file (eager) ==\n");
    let tmp = std::env::temp_dir().join("cbench_persist_bench");
    let _ = std::fs::remove_dir_all(&tmp);
    std::fs::create_dir_all(&tmp).unwrap();
    let span_ns = 64 * 1_000_000_000; // 64-trigger shards = materialization granularity
    let mut cold_ms: Vec<f64> = Vec::new();
    let mut last_dir = tmp.clone();
    let mut points_100x = 0usize;
    for (mult, per_series) in [(1usize, 100usize), (10, 1000), (100, 10_000)] {
        let mut db = synthetic_db_span(100, per_series, 17, span_ns);
        db.compact(64 * 1_000_000_000);
        let dir = tmp.join(format!("depth{mult}x"));
        db.save(&dir).unwrap();
        points_100x = db.len();
        let shard_count = db.shards("lbm").len();
        let mut b = Bench::new(&format!("cold_load_detect_{mult}x_history"));
        b.budget_secs = 2.0;
        let r = b.run(|| {
            let cold = Db::load(&dir).unwrap();
            det.detect(&cold).len()
        });
        println!("{}   ({} points on disk, {} shards)", r.report(), db.len(), shard_count);
        cold_ms.push(r.secs_per_iter.p50 * 1e3);
        last_dir = dir;
    }
    let legacy = tmp.join("depth100x.lp");
    Db::load(&last_dir).unwrap().export_lp(&legacy).unwrap();
    let mut b = Bench::new("cold_load_detect_100x_legacy_eager");
    b.budget_secs = 2.0;
    let r_eager = b.run(|| {
        let cold = Db::load(&legacy).unwrap();
        det.detect(&cold).len()
    });
    println!("{}", r_eager.report());

    // LRU shard-body cache on the 100× history: cap resident bodies,
    // prove the cap holds through inserts (the eviction hook) while
    // queries stay correct (evicted shards lazily re-materialize), and
    // count evictions / re-materializations via obs::metrics
    println!("\n== shard-body LRU cache (--shard-cache) on the 100x history ==\n");
    om::set_enabled(true);
    let ev0 = om::get(om::Counter::ShardEvictions);
    let rm0 = om::get(om::Counter::ShardRemats);
    let mut capped = Db::load(&last_dir).unwrap();
    let total_shards = capped.shards("lbm").len();
    capped.set_body_cap(Some(4));
    let full = full_scan(&capped); // warms every shard (reads never evict)
    let warm = capped.loaded_bodies();
    assert!(warm > 4, "full scan materializes more bodies than the cap");
    capped.insert(
        Point::new("lbm", 10_000 * 1_000_000_000)
            .tag("case", "uniformgridcpu")
            .tag("node", "node00")
            .tag("collision_op", "srt")
            .tag("commit", "lru-probe")
            .field("mlups", 400.0),
    );
    let after = capped.loaded_bodies();
    assert!(after <= 4 + 1, "insert hook enforces the cap (+1 dirty shard), got {after}");
    let full2 = full_scan(&capped);
    assert_eq!(full, full2, "eviction must be invisible to queries");
    let lru_evictions = om::get(om::Counter::ShardEvictions) - ev0;
    let lru_remats = om::get(om::Counter::ShardRemats) - rm0;
    assert!(lru_evictions > 0 && lru_remats > 0);
    println!(
        "cap 4 of {total_shards} shards: warm={warm} -> {after} after insert; \
         {lru_evictions} evictions, {lru_remats} lazy re-materializations"
    );

    // self-metrics throughput: the rates `--self-metrics on` uploads as
    // `cbench_self` (line-protocol parse, point insert, detector sync) —
    // measured here single-threaded so the counters are exact
    println!("\n== self-metrics (obs::metrics rates) ==\n");
    om::reset();
    om::set_enabled(true);
    let mut lp = String::new();
    for t in 0..2000i64 {
        lp.push_str(&format!(
            "lbm,case=uniformgridcpu,node=node{:02},collision_op=srt mlups={} {}\n",
            t % 10,
            400 + (t % 50),
            t * 1_000_000_000
        ));
    }
    let mut mdb = Db::new();
    let ingested = mdb.ingest_lines(&lp).unwrap();
    assert_eq!(ingested, 2000);
    let mut st = DetectorState::new();
    st.sync(&det, &mdb);
    let snap = om::counters();
    let g = |c: om::Counter| snap[c.idx()];
    let lp_rate = om::rate_per_sec(g(om::Counter::LpLines), g(om::Counter::LpParseNs));
    let ins_rate = om::rate_per_sec(g(om::Counter::InsertPoints), g(om::Counter::InsertNs));
    let sync_rate = om::rate_per_sec(g(om::Counter::SyncPoints), g(om::Counter::SyncNs));
    println!("lp parse   : {:>12.0} lines/s", lp_rate);
    println!("tsdb insert: {:>12.0} points/s", ins_rate);
    println!("state sync : {:>12.0} points/s", sync_rate);
    println!(
        "SELFMETRICS_JSON {{\"lp_lines_per_sec\":{lp_rate:.0},\"insert_points_per_sec\":{ins_rate:.0},\"sync_points_per_sec\":{sync_rate:.0},\"shard_evictions\":{lru_evictions},\"shard_remats\":{lru_remats}}}"
    );
    om::set_enabled(false);

    let (t1, t10, t100) = (cold_ms[0], cold_ms[1], cold_ms[2]);
    let ratio = if t1 > 0.0 { t100 / t1 } else { 1.0 };
    println!(
        "PERSIST_JSON {{\"t_cold_1x_ms\":{t1:.4},\"t_cold_10x_ms\":{t10:.4},\"t_cold_100x_ms\":{t100:.4},\"ratio_100x\":{ratio:.4},\"lazy_load_flat\":{},\"t_eager_100x_ms\":{:.4},\"points_100x\":{points_100x}}}",
        ratio <= 1.10,
        r_eager.secs_per_iter.p50 * 1e3
    );
    let _ = std::fs::remove_dir_all(&tmp);

    // parallel ingest + detect: the zero-copy batched line-protocol parser
    // and the par:: fan-outs (chunked parse, per-shard batch insert,
    // per-series detection) against the serial baseline. One iteration =
    // parse + insert a 200k-line dump into a fresh store, then a full
    // detector sweep — the campaign collect hot path. INGEST_JSON carries
    // the 4-thread speedup; CI gates it at >= 2x (ISSUE 7 acceptance) and
    // the artifacts stay byte-identical for any thread count
    // (prop_parallel_equals_serial).
    println!("\n== parallel ingest + detect (--threads) ==\n");
    // 64 s shards give the 2000 s history ~32 shards, so the per-shard
    // insert fan-out has real jobs (the default 4096 s span would put the
    // whole dump in one shard and serialize the insert phase)
    let ingest_span = 64 * 1_000_000_000;
    let ingest_src = synthetic_db_span(100, 2000, 23, ingest_span);
    let ingest_points = ingest_src.len();
    let lp_path = std::env::temp_dir().join("cbench_ingest_bench.lp");
    ingest_src.export_lp(&lp_path).unwrap();
    let lp_text = std::fs::read_to_string(&lp_path).unwrap();
    let _ = std::fs::remove_file(&lp_path);
    drop(ingest_src);
    let mut ingest_ms: Vec<(usize, f64)> = Vec::new();
    for threads in [1usize, 2, 4, 8] {
        cbench::par::set_threads(threads);
        let mut b = Bench::new(&format!("ingest_detect_200k_t{threads}"));
        b.budget_secs = 3.0;
        let r = b.run(|| {
            let mut db = Db::with_shard_span(ingest_span);
            let n = db.ingest_lines(&lp_text).unwrap();
            n + det.detect(&db).len()
        });
        println!(
            "{}   ({} points)",
            r.report_throughput(ingest_points as f64, "point"),
            ingest_points
        );
        ingest_ms.push((threads, r.secs_per_iter.p50 * 1e3));
    }
    cbench::par::set_threads(0);
    let ms_at = |t: usize| ingest_ms.iter().find(|(n, _)| *n == t).unwrap().1;
    let speedup_4x = if ms_at(4) > 0.0 { ms_at(1) / ms_at(4) } else { 1.0 };
    println!(
        "INGEST_JSON {{\"points\":{ingest_points},\"t1_ms\":{:.4},\"t2_ms\":{:.4},\"t4_ms\":{:.4},\"t8_ms\":{:.4},\"speedup_4x\":{speedup_4x:.4},\"ge2x_at_4\":{}}}",
        ms_at(1),
        ms_at(2),
        ms_at(4),
        ms_at(8),
        speedup_4x >= 2.0
    );

    // allocation economy: columnar ingest vs the per-point replay on the
    // same 10k-line slice, counted by the process-wide counting
    // allocator. The per-point path parses every line into an owned
    // Point (BTreeMaps of owned Strings) and inserts it; the columnar
    // path interns measurement/tag/field strings once and appends to
    // structure-of-arrays columns. The in-run A/B ratio is the portable
    // gate (CI: <= 0.25); absolute counts vary with allocator and libstd.
    println!("\n== allocations per ingested point (columnar vs per-point) ==\n");
    cbench::par::set_threads(1); // single-threaded: the counter is exact
    let slice: String = lp_text
        .lines()
        .take(10_000)
        .flat_map(|l| [l, "\n"])
        .collect();
    let n_slice = slice.lines().count();
    let legacy_allocs = {
        let mut db = Db::with_shard_span(ingest_span);
        let a0 = ALLOCS.load(Ordering::Relaxed);
        for line in slice.lines() {
            db.insert(Point::parse_line(line).unwrap());
        }
        ALLOCS.load(Ordering::Relaxed) - a0
    };
    let col_db;
    let col_allocs = {
        let mut db = Db::with_shard_span(ingest_span);
        let a0 = ALLOCS.load(Ordering::Relaxed);
        let n = db.ingest_lines(&slice).unwrap();
        let d = ALLOCS.load(Ordering::Relaxed) - a0;
        assert_eq!(n, n_slice);
        col_db = db;
        d
    };
    cbench::par::set_threads(0);
    let legacy_per_point = legacy_allocs as f64 / n_slice as f64;
    let col_per_point = col_allocs as f64 / n_slice as f64;
    let alloc_ratio = if legacy_per_point > 0.0 {
        col_per_point / legacy_per_point
    } else {
        1.0
    };
    let istats = col_db.interner_stats();
    println!("  per-point path: {legacy_per_point:.1} allocs/point");
    println!(
        "  columnar path : {col_per_point:.1} allocs/point ({:.1}% of per-point)",
        alloc_ratio * 100.0
    );
    println!(
        "  interner      : {} strings / {} tag sets, ~{} bytes resident",
        istats.strings, istats.tagsets, istats.approx_bytes
    );
    println!(
        "MEMORY_JSON {{\"points\":{n_slice},\"allocs_per_point_legacy\":{legacy_per_point:.3},\"allocs_per_point_columnar\":{col_per_point:.3},\"ratio\":{alloc_ratio:.4},\"le_quarter\":{},\"interner_strings\":{},\"interner_tagsets\":{},\"interner_bytes\":{}}}",
        alloc_ratio <= 0.25,
        istats.strings,
        istats.tagsets,
        istats.approx_bytes
    );

    // statistical primitives on window-sized samples
    let mut rng = Rng::new(1);
    let a: Vec<f64> = (0..100).map(|_| rng.gauss(1000.0, 10.0)).collect();
    let c: Vec<f64> = (0..100).map(|_| rng.gauss(950.0, 10.0)).collect();
    let mut b = Bench::new("welch_t_100v100");
    let r = b.run(|| welch_t(&a, &c).unwrap().p);
    println!("{}", r.report());

    let mut b = Bench::new("mann_whitney_100v100");
    let r = b.run(|| mann_whitney(&a, &c).unwrap().p);
    println!("{}", r.report());

    let long: Vec<f64> = (0..1000)
        .map(|i| {
            if i < 600 {
                rng.gauss(100.0, 2.0)
            } else {
                rng.gauss(90.0, 2.0)
            }
        })
        .collect();
    let mut b = Bench::new("cusum_changepoint_1k");
    let r = b.run(|| cusum_changepoint(&long).index);
    println!("{}", r.report_throughput(1000.0, "point"));
}
