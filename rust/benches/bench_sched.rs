//! Scheduler + campaign benchmarks: the event engine's throughput, and
//! the tentpole number — overlapped pipelines vs back-to-back sequential
//! on the shared Testcluster, including the 80-job FE2TI matrix.
//!
//! `cargo bench --bench bench_sched`

use cbench::cluster::nodes::catalogue;
use cbench::coordinator::campaign::{
    default_projects, run_campaign, CampaignConfig, CampaignProject, ProjectKind,
};
use cbench::coordinator::CbSystem;
use cbench::sched::{JobOutcome, SimScheduler, SubmitSpec};
use cbench::util::stats::Bench;

fn main() {
    println!("== bench_sched: event-driven scheduler + campaign overlap ==\n");

    // event-engine throughput: 2000 jobs, 2 owners, mixed priorities
    let mut b = Bench::new("sched_2000_jobs_event_engine");
    b.budget_secs = 2.0;
    let r = b.run(|| {
        let mut s =
            SimScheduler::new(catalogue().into_iter().filter(|n| n.testcluster).collect());
        let hosts: Vec<String> = s.nodes().map(|n| n.host.to_string()).collect();
        for i in 0..2000 {
            s.submit(
                SubmitSpec::new(&format!("j{i}"), &hosts[i % hosts.len()])
                    .owner(if i % 2 == 0 { "repo-a" } else { "repo-b" })
                    .priority((i % 3) as i64),
                Box::new(|_n, _t| JobOutcome {
                    duration: 1.0,
                    stdout: String::new(),
                    exit_code: 0,
                }),
            )
            .unwrap();
        }
        s.run_until_idle().len()
    });
    println!("{}", r.report_throughput(2000.0, "job"));

    // the tentpole: 2 repos (waLBerla 55-job + FE2TI 100-job matrices) x
    // 2 pushes, every pipeline overlapped on one scheduler — simulated
    // makespan vs the back-to-back sequential baseline
    println!("\n== campaign overlap vs sequential (simulated time) ==\n");
    let t = std::time::Instant::now();
    let mut cb = CbSystem::new();
    let mut projects = default_projects(2); // walberla-0 + fe2ti-1
    let out = run_campaign(
        &mut cb,
        &mut projects,
        &CampaignConfig { pushes: 2, penalty: 0.0, seed: 1, ..CampaignConfig::default() },
    )
    .unwrap();
    println!(
        "2 repos x 2 pushes: {} pipelines / {} jobs (host time {})",
        out.reports.len(),
        out.total_jobs(),
        cbench::util::fmt_secs(t.elapsed().as_secs_f64())
    );
    println!(
        "  overlapped makespan   : {}",
        cbench::util::fmt_secs(out.makespan)
    );
    println!(
        "  sequential baseline   : {}",
        cbench::util::fmt_secs(out.sequential_baseline)
    );
    println!(
        "  overlap speedup       : {:.2}x {}",
        out.overlap_speedup(),
        if out.makespan < out.sequential_baseline {
            "(makespan BELOW sequential)"
        } else {
            "(no win on this job set)"
        }
    );

    // scaling the fleet: more repos sharing the same cluster
    for repos in [4usize, 6] {
        let mut cb = CbSystem::new();
        let mut projects = default_projects(repos);
        let out = run_campaign(
            &mut cb,
            &mut projects,
            &CampaignConfig { pushes: 1, penalty: 0.0, seed: 1, ..CampaignConfig::default() },
        )
        .unwrap();
        println!(
            "{repos} repos x 1 push : makespan {} vs sequential {} ({:.2}x)",
            cbench::util::fmt_secs(out.makespan),
            cbench::util::fmt_secs(out.sequential_baseline),
            out.overlap_speedup()
        );
    }

    // streaming vs batch collection on the same roster: identical
    // schedule and makespan, but streaming's first upload lands at the
    // first pipeline's completion instead of after the drain
    println!("\n== streaming vs batch collect (time to first upload, simulated) ==\n");
    let collect_run = |streaming: bool| {
        let mut cb = CbSystem::new();
        let mut projects = default_projects(2);
        let out = run_campaign(
            &mut cb,
            &mut projects,
            &CampaignConfig {
                pushes: 2,
                penalty: 0.0,
                seed: 1,
                streaming,
                ..CampaignConfig::default()
            },
        )
        .unwrap();
        (out.first_upload_at(), out.makespan)
    };
    let (first_s, mk_s) = collect_run(true);
    let (first_b, mk_b) = collect_run(false);
    assert_eq!(mk_s, mk_b, "collect mode must not change the schedule");
    println!(
        "  streaming: first upload {} (makespan {})",
        cbench::util::fmt_secs(first_s),
        cbench::util::fmt_secs(mk_s)
    );
    println!(
        "  batch    : first upload {} (makespan {})",
        cbench::util::fmt_secs(first_b),
        cbench::util::fmt_secs(mk_b)
    );
    println!(
        "STREAM_JSON {{\"first_upload_streaming_s\":{first_s:.3},\"first_upload_batch_s\":{first_b:.3},\"makespan_s\":{mk_s:.3},\"improved\":{}}}",
        first_s < first_b
    );

    // priority lanes: a high-priority repo pushes into a busy cluster
    let mut cb = CbSystem::new();
    let mut projects = vec![
        CampaignProject::new("bulk-0", ProjectKind::Walberla),
        CampaignProject::new("bulk-1", ProjectKind::Walberla),
        CampaignProject::new("urgent", ProjectKind::Walberla).priority(10),
    ];
    let out = run_campaign(
        &mut cb,
        &mut projects,
        &CampaignConfig { pushes: 1, penalty: 0.0, seed: 2, ..CampaignConfig::default() },
    )
    .unwrap();
    let urgent = out.reports.iter().find(|r| r.repo == "urgent").unwrap();
    let bulk_wall: f64 = out
        .reports
        .iter()
        .filter(|r| r.repo != "urgent")
        .map(|r| r.duration)
        .fold(0.0, f64::max);
    println!(
        "priority lane        : urgent pipeline wall {} vs slowest bulk {}",
        cbench::util::fmt_secs(urgent.duration),
        cbench::util::fmt_secs(bulk_wall)
    );

    // the gap-heavy roster: maintenance windows + mixed timelimits, the
    // same submissions dispatched with and without conservative backfill.
    // Backfill-on must come in strictly below backfill-off here — the
    // acceptance number of the backfill refactor (BACKFILL_JSON is
    // embedded into the per-commit bench history by CI).
    println!("\n== backfill on/off on a gap-heavy roster (simulated time) ==\n");
    let gap_heavy = |backfill: bool| -> (f64, usize) {
        let mut s =
            SimScheduler::new(catalogue().into_iter().filter(|n| n.testcluster).collect());
        s.set_backfill(backfill);
        // three nodes drained mid-roster; long-limit jobs cannot start in
        // front of the windows, short-limit jobs can
        for host in ["icx36", "rome1", "genoa2"] {
            s.maintenance(host, 240.0, 4000.0).unwrap();
        }
        let hosts = ["icx36", "rome1", "genoa2", "medusa"];
        let mut n = 0u64;
        for i in 0..48 {
            let host = hosts[i % hosts.len()];
            // alternate hour-scale and minute-scale timelimits; distinct
            // priorities keep the dispatch order fair-share-independent
            let (tl_min, dur) = if i % 3 == 0 { (90.0, 600.0) } else { (2.0, 45.0) };
            s.submit(
                SubmitSpec::new(&format!("g{i}"), host)
                    .timelimit(tl_min)
                    .priority(1000 - i as i64)
                    .owner(if i % 2 == 0 { "repo-a" } else { "repo-b" }),
                Box::new(move |_n, _t| JobOutcome {
                    duration: dur,
                    stdout: String::new(),
                    exit_code: 0,
                }),
            )
            .unwrap();
            n += 1;
        }
        s.run_until_idle();
        let backfills = s.jobs().filter(|j| j.backfilled).count();
        assert_eq!(s.jobs().count() as u64, n);
        (s.now(), backfills)
    };
    let (makespan_on, backfills_on) = gap_heavy(true);
    let (makespan_off, backfills_off) = gap_heavy(false);
    println!(
        "  backfill on : makespan {} ({} backfilled starts)",
        cbench::util::fmt_secs(makespan_on),
        backfills_on
    );
    println!(
        "  backfill off: makespan {} ({} backfilled starts)",
        cbench::util::fmt_secs(makespan_off),
        backfills_off
    );
    println!(
        "  {}",
        if makespan_on < makespan_off {
            "backfill-on makespan strictly BELOW backfill-off"
        } else {
            "no win on this roster"
        }
    );
    println!(
        "BACKFILL_JSON {{\"makespan_on_s\":{makespan_on:.3},\"makespan_off_s\":{makespan_off:.3},\"backfilled_jobs\":{backfills_on},\"improved\":{}}}",
        makespan_on < makespan_off
    );

    // fleet scale: a day of open-loop push arrivals (submit_at) swept by
    // one event queue, timeline formatting off — the capacity number of
    // the interned/indexed scheduler core. CBENCH_FLEET_JOBS overrides
    // the job count (CI may dial it down).
    println!("\n== fleet-scale event engine (open-loop arrivals, timeline off) ==\n");
    let fleet_jobs: usize = std::env::var("CBENCH_FLEET_JOBS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(1_000_000);
    let mut s = SimScheduler::new(catalogue().into_iter().filter(|n| n.testcluster).collect());
    s.set_timeline(false);
    let hosts: Vec<String> = s.nodes().map(|n| n.host.to_string()).collect();
    let owners = [
        "repo-a", "repo-b", "repo-c", "repo-d", "repo-e", "repo-f", "repo-g", "repo-h",
    ];
    let t = std::time::Instant::now();
    // ~10 arrivals per simulated second against ~11 nodes of 1 s jobs:
    // slightly undersubscribed, so queues stay shallow and the number
    // measures the engine, not a pile-up
    for i in 0..fleet_jobs {
        s.submit_at(
            SubmitSpec::new(&format!("f{i}"), &hosts[i % hosts.len()])
                .owner(owners[i % owners.len()])
                .priority((i % 5) as i64),
            Box::new(|_n, _t| JobOutcome {
                duration: 1.0,
                stdout: String::new(),
                exit_code: 0,
            }),
            i as f64 * 0.1,
        )
        .unwrap();
    }
    let submit_s = t.elapsed().as_secs_f64();
    let t = std::time::Instant::now();
    let mut events = 0u64;
    while s.step().is_some() {
        events += 1;
    }
    let drive_s = t.elapsed().as_secs_f64();
    let done = s.jobs().filter(|j| j.state.is_terminal()).count();
    assert_eq!(done, fleet_jobs, "every fleet job must reach a terminal state");
    let events_per_sec = events as f64 / drive_s.max(1e-9);
    let dispatch_us_per_job = (submit_s + drive_s) * 1e6 / fleet_jobs as f64;
    println!(
        "  {} jobs / {} events on {} nodes, {} owners interned",
        fleet_jobs,
        events,
        hosts.len(),
        s.owner_count()
    );
    println!(
        "  submit {} + drive {} -> {:.0} events/s, {:.3} us/job end to end",
        cbench::util::fmt_secs(submit_s),
        cbench::util::fmt_secs(drive_s),
        events_per_sec,
        dispatch_us_per_job
    );
    println!("  peak event-queue depth: {}", s.peak_queue_depth());
    println!(
        "FLEET_JSON {{\"jobs\":{fleet_jobs},\"events\":{events},\"events_per_sec\":{events_per_sec:.0},\"dispatch_us_per_job\":{dispatch_us_per_job:.3},\"peak_queue_depth\":{},\"owners\":{}}}",
        s.peak_queue_depth(),
        s.owner_count()
    );
}
