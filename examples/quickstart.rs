//! Quickstart: push one commit through the full CB pipeline and look at
//! the results — the 60-second tour of the system.
//!
//! Run: `cargo run --release --example quickstart`

use cbench::coordinator::{walberla_pipeline::walberla_pipeline_jobs, CbSystem};
use cbench::dashboard::walberla_dashboard;
use cbench::tsdb::{Aggregate, Query};
use cbench::vcs::Repository;

fn main() -> anyhow::Result<()> {
    // 1. a repository with one commit (the thing CB watches)
    let mut repo = Repository::new("walberla");
    let event = repo.commit_change(
        "master",
        "you",
        "quickstart: initial kernels",
        0.0,
        "benchmark.cfg",
        "# no special flags\n",
    );
    println!("committed {} to {}/master", &event.commit_id[..8], event.repo);

    // 2. the CB installation: simulated Testcluster + scheduler + TSDB +
    //    record store + dashboards
    let mut cb = CbSystem::new();

    // 3. the push triggers the pipeline: job matrix over every node ×
    //    collision operator, submitted via the Slurm-like scheduler
    let jobs = walberla_pipeline_jobs(&repo, &event.commit_id);
    println!("pipeline generated {} benchmark jobs", jobs.len());
    let report = cb.execute_pipeline(&event, true, jobs, "lbm")?;
    println!(
        "pipeline #{}: {}/{} jobs completed, {} metric points uploaded, {} records archived, \
         cluster busy for {}",
        report.pipeline_id,
        report.jobs_completed,
        report.jobs_total,
        report.points_uploaded,
        report.records_created,
        cbench::util::fmt_secs(report.duration),
    );

    // 4. query like a developer: who is fastest per node?
    println!("\nlatest MLUP/s per node (srt):");
    for (label, v) in Query::new("lbm", "mlups")
        .where_tag("collision_op", "srt")
        .group_by(&["node"])
        .run_agg(&cb.db, Aggregate::Last)
    {
        println!("  {label:<16} {v:>9.0}");
    }

    // 5. the dashboard view (with the collision-operator filter)
    let mut dash = walberla_dashboard();
    dash.select("collision_op", &["srt", "trt"]);
    dash.select("node", &["icx36", "genoa2"]);
    println!("\n{}", dash.render_text(&cb.db));
    Ok(())
}
