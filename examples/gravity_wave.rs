//! Gravity wave: the FSLBM benchmark end to end, with real free-surface
//! physics on the host plus the Fig. 13/14 phase analysis.
//!
//! Run: `cargo run --release --example gravity_wave`

use cbench::apps::walberla::collision::CollisionOp;
use cbench::apps::walberla::fslbm::{gravity_wave_phases, FsBlock};
use cbench::cluster::nodes::node;
use cbench::cluster::WorkProfile;
use cbench::mpisim::{CommModel, Geometry};
use cbench::util::table::{series_plot, stacked_bar, Table};

fn main() {
    // ---- real simulation: a 24x24x8 gravity wave, watched over time ----
    let mut b = FsBlock::new(24, 24, 8);
    b.gravity = 3e-4;
    b.init_gravity_wave(0.15);
    let (g0, i0, l0) = b.state_counts();
    println!("initialized gravity wave: {g0} gas / {i0} interface / {l0} liquid cells");
    let m0 = b.total_mass();

    let spread = |b: &FsBlock| {
        let (mut lo, mut hi) = (f64::MAX, f64::MIN);
        for x in 1..=b.nx {
            let h = b.surface_height(x);
            lo = lo.min(h);
            hi = hi.max(h);
        }
        hi - lo
    };
    let mut series = Vec::new();
    let mut work_total = WorkProfile::new(0.0, 0.0);
    for step in 0..=120 {
        if step > 0 {
            let w = b.step(CollisionOp::Srt);
            work_total.add(&w.compute_total());
        }
        if step % 10 == 0 {
            series.push((step as f64, spread(&b)));
        }
    }
    let m1 = b.total_mass();
    println!(
        "after 120 steps: surface spread {:.3} -> {:.3} lattice cells (wave relaxing under gravity)",
        series[0].1,
        series.last().unwrap().1
    );
    println!(
        "mass conservation: {m0:.3} -> {m1:.3} ({:+.4}%)",
        100.0 * (m1 - m0) / m0
    );
    println!(
        "counted work: {:.2e} FLOP, {:.2e} bytes ({:.0} FLOP/cell/step)\n",
        work_total.flops,
        work_total.bytes,
        work_total.flops / (24.0 * 24.0 * 8.0 * 120.0)
    );
    println!("wave amplitude over time:\n{}", series_plot(&[("spread".into(), series)], 10, 60));

    // ---- Fig. 13: phase distribution per architecture ----
    println!("== phase distribution (32^3 cells/core, artificial barriers) ==\n");
    let wpc = WorkProfile::new(550.0, 500.0);
    let comm = CommModel::default();
    for host in ["skylakesp2", "icx36", "rome1", "genoa2"] {
        let n = node(host).unwrap();
        let geometry = Geometry::pure_mpi(1, n.cores());
        let ph = gravity_wave_phases(&n, &geometry, 32, &comm, &wpc);
        let (c, s, m) = ph.shares();
        println!(
            "{}",
            stacked_bar(host, &[("compute", c), ("sync", s), ("xchg-comm", m)], 50)
        );
    }

    // ---- Fig. 14: weak scaling on Fritz ----
    println!("\n== weak scaling on Fritz, 64^3 cells/core ==\n");
    let fritz = node("fritz").unwrap();
    let mut t = Table::new(&["nodes", "total [ms]", "compute", "sync", "comm"]);
    for nodes in [1usize, 2, 4, 8, 16, 32, 64] {
        let geometry = Geometry::pure_mpi(nodes, fritz.cores());
        let ph = gravity_wave_phases(&fritz, &geometry, 64, &comm, &wpc);
        t.row(&[
            nodes.to_string(),
            format!("{:.3}", ph.total() * 1e3),
            format!("{:.3}", ph.compute * 1e3),
            format!("{:.3}", ph.sync * 1e3),
            format!("{:.3}", ph.comm * 1e3),
        ]);
    }
    println!("{}", t.render());
    println!("(note the comm jump between 4 and 8 nodes — allocation topology — and the");
    println!("steadily growing sync share; compute stays flat: the Fig. 14 signature.)");
}
