//! Solver study: the FE2TI §5.1 story as a standalone experiment.
//!
//! Reproduces, at our scale, the chain of findings the CB pipeline
//! surfaced for FE2TI: ILU with relaxed tolerance is fastest, PARDISO
//! achieves the highest FLOP rate, UMFPACK's speed hinges on the linked
//! BLAS, Newton still converges with inexact micro solves, and the micro
//! phase weak-scales while a sequential macro solve does not.
//!
//! Run: `cargo run --release --example solver_study`

use cbench::apps::fe2ti::bench::{run_fe2ti_benchmark, Fe2tiCase, Fe2tiRun, Parallelization};
use cbench::apps::fe2ti::solvers::{BlasLib, Compiler, SolverConfig, SolverKind};
use cbench::cluster::nodes::node;
use cbench::util::table::Table;

fn main() {
    let icx = node("icx36").unwrap();

    println!("== fe2ti216 on icx36 (72 MPI ranks), all solver packages ==\n");
    let mut t = Table::new(&[
        "solver", "compiler", "BLAS", "TTS [s]", "GFLOP/s", "OI", "Newton", "verif.err",
    ]);
    let mut configs: Vec<SolverConfig> = Vec::new();
    for compiler in [Compiler::Intel, Compiler::Gcc] {
        for kind in SolverKind::paper_set() {
            configs.push(SolverConfig::new(kind, compiler));
        }
    }
    // the post-fix UMFPACK build (paper Fig. 10b)
    configs.push(SolverConfig::new(SolverKind::Umfpack, Compiler::Gcc).with_blas(BlasLib::Blis));

    let mut fastest: Option<(String, f64)> = None;
    for cfg in &configs {
        let run = Fe2tiRun::new(Fe2tiCase::Fe2ti216, *cfg, Parallelization::MpiOnly);
        let r = run_fe2ti_benchmark(&run, &icx, 1);
        t.row(&[
            cfg.kind.name(),
            cfg.compiler.name().to_string(),
            cfg.umfpack_blas.name().to_string(),
            format!("{:.4}", r.tts),
            format!("{:.1}", r.gflops),
            format!("{:.3}", r.oi),
            r.newton_iters.to_string(),
            format!("{:.1e}", r.verification_error),
        ]);
        if fastest.as_ref().map(|(_, t0)| r.tts < *t0).unwrap_or(true) {
            fastest = Some((cfg.label(), r.tts));
        }
    }
    println!("{}", t.render());
    let (name, tts) = fastest.unwrap();
    println!("fastest configuration: {name} at {tts:.4} s — the paper's conclusion:");
    println!("\"the fastest solution is to use an inexact solver for the micro problems\",");
    println!("and it needs no vendor-specific library (works on AMD nodes too).\n");

    println!("== parallelization modes (fe2ti216, ILU 1e-4) ==\n");
    let cfg = SolverConfig::new(SolverKind::Ilu { tol: 1e-4 }, Compiler::Intel);
    let mut t2 = Table::new(&["mode", "TTS [s]", "micro [s]", "OpenMP overhead [s]"]);
    for par in [
        Parallelization::MpiOnly,
        Parallelization::OmpOnly,
        Parallelization::Hybrid,
    ] {
        let run = Fe2tiRun::new(Fe2tiCase::Fe2ti216, cfg, par);
        let r = run_fe2ti_benchmark(&run, &icx, 1);
        t2.row(&[
            par.name().to_string(),
            format!("{:.4}", r.tts),
            format!("{:.4}", r.micro_time),
            format!("{:.4}", r.omp_overhead),
        ]);
    }
    println!("{}", t2.render());
    println!("(pure MPI is slightly faster for the micro solves — OpenMP runtime overhead,");
    println!("exactly the paper's single-node observation in Fig. 11.)\n");

    println!("== benchmark mode: fe2ti1728 (1728 RVEs, 216 solved, macro precomputed) ==\n");
    let run = Fe2tiRun::new(Fe2tiCase::Fe2ti1728, cfg, Parallelization::Hybrid);
    let r = run_fe2ti_benchmark(&run, &icx, 1);
    println!(
        "TTS {:.4} s, micro {:.4} s, macro {:.4} s (skipped), verification error {:.1e}",
        r.tts, r.micro_time, r.macro_time, r.verification_error
    );
}
