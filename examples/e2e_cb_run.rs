//! END-TO-END driver: a multi-commit continuous-benchmarking campaign on
//! a real (small) workload, proving all layers compose —
//!
//!   vcs commits → CI trigger (incl. proxy-repo flow) → Slurm job matrix
//!   over the simulated Testcluster → real benchmark execution (FE2TI
//!   nested Newton with real sparse solvers; waLBerla LBM — including the
//!   **JAX/Pallas AOT kernel executed through PJRT** on this host) →
//!   likwid-style parsing → TSDB + Kadi-style records → dashboards →
//!   automatic regression detection.
//!
//! The campaign plants two code events the paper describes:
//!   * commit 3 on walberla introduces a kernel regression (-15% MLUP/s)
//!     — CB must flag it (paper §3/§7);
//!   * commit 2 on fe2ti links the gcc build against BLIS — CB must show
//!     the UMFPACK TTS drop (paper Fig. 10b).
//!
//! Run: `cargo run --release --example e2e_cb_run`
//! Results are recorded in EXPERIMENTS.md §End-to-end.

use cbench::apps::walberla::collision::CollisionOp;
use cbench::apps::walberla::grid::Block;
use cbench::apps::walberla::lattice::d3q19;
use cbench::coordinator::{
    detect_regressions, fe2ti_pipeline::fe2ti_pipeline_jobs,
    walberla_pipeline::walberla_pipeline_jobs, CbSystem,
};
use cbench::dashboard::{fe2ti_dashboard, walberla_dashboard};
use cbench::tsdb::{Aggregate, Query};
use cbench::vcs::{ProxyRepo, Repository};
use std::time::Instant;

fn main() -> anyhow::Result<()> {
    let t_start = Instant::now();
    let mut cb = CbSystem::new();

    // ------------------------------------------------------------------
    // Layer check first: the AOT Pallas kernel through PJRT vs the native
    // rust kernel on the same lattice — the lbmpy-analogue code path.
    // ------------------------------------------------------------------
    println!("=== PJRT artifact validation (L1/L2 -> L3 bridge) ===");
    match cbench::runtime::Engine::open("artifacts") {
        Ok(mut engine) => {
            let n = 16usize;
            let mut block = Block::new(d3q19(), n, n, n);
            block.init_equilibrium(1.0, [0.02, -0.01, 0.005]);
            // native step
            let mut native = Block::new(d3q19(), n, n, n);
            native.init_equilibrium(1.0, [0.02, -0.01, 0.005]);
            native.step(CollisionOp::Srt, 0.6);
            // artifact step (collide+stream fused in the HLO)
            let f = block.to_artifact_layout();
            let t0 = Instant::now();
            let out = engine.lbm_step("lbm_d3q19_srt_16", &f)?;
            let dt = t0.elapsed().as_secs_f64();
            block.from_artifact_layout(&out);
            // compare macroscopic fields
            let mut max_du = 0.0f64;
            for x in 1..=n {
                for y in 1..=n {
                    for z in 1..=n {
                        let (r1, u1) = native.cell_moments(x, y, z);
                        let (r2, u2) = block.cell_moments(x, y, z);
                        max_du = max_du.max((r1 - r2).abs());
                        for i in 0..3 {
                            max_du = max_du.max((u1[i] - u2[i]).abs());
                        }
                    }
                }
            }
            let mlups = (n * n * n) as f64 / dt / 1e6;
            println!(
                "pallas-artifact vs native rust kernel: max moment deviation {max_du:.2e} \
                 (f32 vs f64 tolerance), PJRT step {:.2} ms = {mlups:.2} MLUP/s host-measured",
                dt * 1e3
            );
            anyhow::ensure!(max_du < 1e-4, "artifact and native kernels disagree");
        }
        Err(e) => println!("artifacts not built ({e}); run `make artifacts` — continuing"),
    }

    // ------------------------------------------------------------------
    // FE2TI campaign: 2 commits; the second is the BLAS fix.
    // ------------------------------------------------------------------
    println!("\n=== FE2TI campaign (direct-push pipeline) ===");
    let mut fe2ti = Repository::new("fe2ti");
    let commits = [
        ("baseline solvers", "# defaults\n"),
        ("link gcc build against BLIS (fixes UMFPACK)", "umfpack_blas = blis\n"),
    ];
    for (i, (msg, cfg)) in commits.iter().enumerate() {
        let ev = fe2ti.commit_change("master", "alice", msg, i as f64 * 3600.0, "benchmark.cfg", cfg);
        let jobs = fe2ti_pipeline_jobs(&fe2ti, &ev.commit_id);
        let r = cb.execute_pipeline(&ev, false, jobs, "fe2ti")?;
        println!(
            "commit {} ({msg}): {} jobs, {} points, cluster time {}",
            &ev.commit_id[..8],
            r.jobs_total,
            r.points_uploaded,
            cbench::util::fmt_secs(r.duration)
        );
    }
    // the Fig. 10b signal: UMFPACK/gcc TTS must have dropped sharply
    let improvements: Vec<_> = Query::new("fe2ti", "tts")
        .where_tag("solver", "umfpack")
        .where_tag("compiler", "gcc")
        .where_tag("node", "skylakesp2")
        .where_tag("parallelization", "mpi")
        .where_tag("case", "fe2ti216")
        .run(&cb.db);
    let s = &improvements[0];
    let (before, after) = (s.points[0].1, s.points[s.points.len() - 1].1);
    println!(
        "UMFPACK/gcc TTS on skylakesp2: {before:.4} s -> {after:.4} s ({:.1}x speedup from the BLAS fix)",
        before / after
    );
    anyhow::ensure!(after < 0.5 * before, "BLAS fix must show in the TSDB");

    // ------------------------------------------------------------------
    // waLBerla campaign via the proxy repository: baseline, regression,
    // fix — CB must catch the regression.
    // ------------------------------------------------------------------
    println!("\n=== waLBerla campaign (proxy-repo trigger) ===");
    let mut upstream = Repository::new("walberla");
    let mut proxy = ProxyRepo::new("walberla", "walberla-cb-proxy", &["carol"]);
    let commits = [
        ("baseline kernels", "# defaults\n"),
        ("refactor kernel generation (hides a regression)", "lbm_efficiency_penalty = 0.15\n"),
        ("fix kernel generation regression", "lbm_efficiency_penalty = 0.0\n"),
    ];
    for (i, (msg, cfg)) in commits.iter().enumerate() {
        let up_ev = upstream.commit_change("master", "dev", msg, i as f64 * 3600.0, "benchmark.cfg", cfg);
        let ev = proxy
            .trigger(&upstream, &up_ev.commit_id, "master", "carol")
            .map_err(|e| anyhow::anyhow!(e))?;
        let jobs = walberla_pipeline_jobs(&proxy.proxy, &ev.commit_id);
        let r = cb.execute_pipeline(&ev, true, jobs, "lbm")?;
        println!(
            "commit {} ({msg}): {} jobs, {} points",
            &ev.commit_id[..8],
            r.jobs_total,
            r.points_uploaded
        );
        // CB's core promise: immediate feedback after every pipeline
        let regs = detect_regressions(&cb.db, "lbm", "mlups", &["node", "collision_op"], 0.10, true);
        if regs.is_empty() {
            println!("  regression check: clean");
        } else {
            println!("  regression check: {} series degraded, e.g.:", regs.len());
            for r in regs.iter().take(3) {
                println!(
                    "    {}: {:.0} -> {:.0} MLUP/s ({:+.1}%)",
                    r.series,
                    r.before,
                    r.after,
                    100.0 * r.rel_change
                );
            }
            anyhow::ensure!(i == 1, "regression flagged on a clean commit!");
        }
    }
    // after the fix, the check must be clean again and throughput restored
    let regs = detect_regressions(&cb.db, "lbm", "mlups", &["node", "collision_op"], 0.10, true);
    anyhow::ensure!(regs.is_empty(), "fix commit should clear the regression");

    // ------------------------------------------------------------------
    // Headline numbers + dashboards.
    // ------------------------------------------------------------------
    println!("\n=== campaign summary ===");
    println!(
        "pipelines executed: {}   total jobs: {}   TSDB points: {}   records: {}   links: {}",
        cb.executed.len(),
        cb.executed.iter().map(|r| r.jobs_total).sum::<usize>(),
        cb.db.len(),
        cb.store.n_records(),
        cb.store.n_links(),
    );
    let busy: f64 = cb.executed.iter().map(|r| r.duration).sum();
    println!(
        "simulated cluster time: {}   real host time: {}",
        cbench::util::fmt_secs(busy),
        cbench::util::fmt_secs(t_start.elapsed().as_secs_f64())
    );
    println!("\nbest LBM throughput per node (last pipeline):");
    for (label, v) in Query::new("lbm", "mlups")
        .where_tag("collision_op", "srt")
        .group_by(&["node"])
        .run_agg(&cb.db, Aggregate::Last)
    {
        println!("  {label:<18} {v:>9.0} MLUP/s");
    }
    let mut fdash = fe2ti_dashboard();
    fdash.select("node", &["icx36"]);
    fdash.select("parallelization", &["mpi"]);
    println!("\n{}", fdash.render_text(&cb.db));
    let mut wdash = walberla_dashboard();
    wdash.select("node", &["icx36"]);
    println!("{}", wdash.render_text(&cb.db));

    cb.db.save(std::path::Path::new("e2e_tsdb.lp"))?;
    println!("TSDB saved to e2e_tsdb.lp — rerun dashboards with `cbench dashboard --tsdb e2e_tsdb.lp`");
    Ok(())
}
